//! The int8 GEMM kernels (see the module docs in [`super`]).
//!
//! Bit-exactness contract (revised for SIMD dispatch): every output
//! cell of every kernel here is the i32 sum `Σ_k a[k]·b[k]` of int8
//! products.  Under the §IV-A shape limits enforced by
//! [`crate::model::ModelConfig::validate`] no partial sum can overflow
//! i32 — and i32 addition without overflow is exactly associative and
//! commutative, so **any accumulation order** (the ascending-k scalar
//! loop, `NR`-lane register blocking, AVX2 `_mm256_madd_epi16` pairwise
//! reduction, horizontal sums) yields the same bits.  The scalar loop
//! of [`dot_i8`] remains the canonical definition; the AVX2 path is
//! pinned to it cell-for-cell by `tests/differential.rs`.
//!
//! The AVX2 kernels sign-extend int8 lanes to i16
//! (`_mm256_cvtepi8_epi16`) and reduce pairs with `_mm256_madd_epi16`
//! — NOT `_mm256_maddubs_epi16`, whose u8×i8 i16 saturation would be
//! inexact.  `madd_epi16` saturates only when both products in a pair
//! are `(-32768)²`, impossible for i8-range inputs, so every lane is
//! exact.

use super::epilogue::Epilogue;
use crate::runtime::pool;
use crate::simd::{self, SimdPath};
use std::cell::Cell;

/// Output units per packed panel (the register-block width of the
/// weights-stationary kernel; 8 i32 accumulator lanes fill exactly one
/// AVX2 register, or two SSE2 registers on the scalar fallback).
pub const NR: usize = 8;

/// Scratch element types eligible for [`resize_for_overwrite`]: plain
/// integers with a recognizable debug-build poison byte pattern.
pub trait ScratchCell: Copy {
    /// Value newly exposed scratch cells are filled with in debug
    /// builds, so a kernel that violates its write-all contract fails
    /// the differential/oracle tests loudly instead of reading
    /// leftover zeros that happen to be correct.
    const POISON: Self;
}

impl ScratchCell for i32 {
    const POISON: i32 = 0x5A5A_5A5A;
}

impl ScratchCell for i8 {
    const POISON: i8 = 0x5A;
}

/// Resize a scratch vector to exactly `n` elements **without**
/// zero-initializing new cells.
///
/// Contract (the reason the zero fill is redundant): every caller
/// passes the result to a kernel that writes **all** `n` cells before
/// any cell is read — the GEMM family writes every output cell, the
/// requant/LayerNorm sweeps write every output element.  Debug builds
/// document and enforce the contract by filling newly exposed cells
/// with [`ScratchCell::POISON`] instead of leaving them arbitrary, so
/// a contract violation produces loud garbage, not silent zeros.
pub fn resize_for_overwrite<T: ScratchCell>(v: &mut Vec<T>, n: usize) {
    if n <= v.len() {
        v.truncate(n);
        return;
    }
    if cfg!(debug_assertions) {
        v.resize(n, T::POISON);
    } else {
        v.reserve(n - v.len());
        // SAFETY: `T` is a plain Copy integer (no drop, every bit
        // pattern valid), the capacity was just reserved, and the
        // write-all contract above guarantees no cell is read before
        // the kernel overwrites it.
        unsafe { v.set_len(n) };
    }
}

/// Activation rows per cache block: a panel (`d_in · NR` int8, ≤ 2 KiB
/// at the repo's widest `d_in = 256`) stays L1-resident while `MC` rows
/// stream through it.  `MC`-row blocks are also the unit of multi-core
/// work distribution ([`crate::runtime::pool::run_blocks`]).
pub const MC: usize = 64;

/// int8 MAC dot product (i32 accumulation, ascending k) — the canonical
/// scalar implementation every kernel in this module reduces to.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        acc += i32::from(x) * i32::from(y);
    }
    acc
}

/// Scalar reference GEMM — the oracle the blocked kernels are
/// property-tested against.  Row-major `x` is `(rows, d_in)`, `w` is
/// `(d_out, d_in)` (one output unit per row), `out` becomes
/// `(rows, d_out)`.  This is the old `norm.rs::matmul_i8` loop, kept
/// verbatim as the obviously-correct baseline (and the scalar side of
/// `benches/gemm.rs`).
pub fn matmul_i8_ref(x: &[i8], d_in: usize, w: &[i8], d_out: usize, out: &mut Vec<i32>) {
    debug_assert!(d_in > 0 && x.len() % d_in == 0);
    debug_assert_eq!(w.len(), d_out * d_in);
    let rows = x.len() / d_in;
    out.resize(rows * d_out, 0);
    for (xrow, orow) in x.chunks_exact(d_in).zip(out.chunks_exact_mut(d_out)) {
        for (o, wrow) in orow.iter_mut().zip(w.chunks_exact(d_in)) {
            *o = dot_i8(xrow, wrow);
        }
    }
}

/// A weight matrix transposed and packed for the blocked GEMM.
///
/// Packing layout (done once, at model construction): output units are
/// grouped into panels of [`NR`]; within a panel the weights are stored
/// k-major with the `NR` units interleaved —
///
/// ```text
/// packed[panel][k][lane] = w[panel·NR + lane][k]      (0 past d_out)
/// ```
///
/// so the inner loop reads one contiguous `NR`-wide stripe per k and
/// broadcasts one activation against it.  The last panel is zero-padded
/// to `NR` (an all-zero weight column contributes nothing, so padding
/// never changes results).  Two consecutive k-stripes are 16 contiguous
/// bytes — exactly one `_mm_loadu_si128` for the AVX2 madd pair.
pub struct PackedGemm {
    /// `ceil(d_out / NR)` panels of `d_in · NR` int8 each.
    packed: Vec<i8>,
    d_in: usize,
    d_out: usize,
}

impl PackedGemm {
    /// Pack row-major `w` of shape `(d_out, d_in)`.
    pub fn pack(w: &[i8], d_out: usize, d_in: usize) -> PackedGemm {
        assert!(d_in > 0 && d_out > 0, "empty GEMM operand");
        assert_eq!(w.len(), d_out * d_in, "w is not (d_out, d_in)");
        let panels = d_out.div_ceil(NR);
        let mut packed = vec![0i8; panels * d_in * NR];
        for p in 0..panels {
            let base = p * d_in * NR;
            for lane in 0..NR {
                let unit = p * NR + lane;
                if unit >= d_out {
                    break; // zero padding already in place
                }
                let wrow = &w[unit * d_in..(unit + 1) * d_in];
                for (k, &wv) in wrow.iter().enumerate() {
                    packed[base + k * NR + lane] = wv;
                }
            }
        }
        PackedGemm { packed, d_in, d_out }
    }

    pub fn d_in(&self) -> usize {
        self.d_in
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Blocked GEMM: `x` is row-major `(rows, d_in)`, `out` becomes
    /// `(rows, d_out)` with `out[r][o] = Σ_k x[r][k]·w[o][k]`.
    ///
    /// Dispatches on [`simd::active`] and spans the current worker pool
    /// (one [`MC`]-row block per work item) — see
    /// [`Self::gemm_into_with_path`].
    pub fn gemm_into(&self, x: &[i8], out: &mut Vec<i32>) {
        self.gemm_into_with_path(simd::active(), x, out);
    }

    /// [`Self::gemm_into`] with an explicit dispatch path (the
    /// differential harness drives both).
    ///
    /// Multi-core dataflow: rows are cut into `MC`-row blocks and
    /// claimed dynamically by the active worker pool
    /// ([`pool::run_blocks`]).  Each block writes a disjoint
    /// `(rend-rb) · d_out` output region, so results are independent of
    /// claim order — thread-count invariance is structural, not
    /// scheduling luck.
    pub fn gemm_into_with_path(&self, path: SimdPath, x: &[i8], out: &mut Vec<i32>) {
        assert!(x.len() % self.d_in == 0, "x is not a whole number of d_in rows");
        let path = simd::require(path);
        let rows = x.len() / self.d_in;
        // The block kernel writes every output cell (all panels × all
        // rows), so the scratch needs no zero fill.
        resize_for_overwrite(out, rows * self.d_out);
        if rows == 0 {
            return;
        }
        let (d_in, d_out) = (self.d_in, self.d_out);
        let nblocks = rows.div_ceil(MC);
        struct SyncPtr(*mut i32);
        // SAFETY: the pointer targets the caller-owned `out` buffer,
        // whose borrow outlives the fan-out (run_blocks blocks until
        // every block completes) and whose rows are written in disjoint
        // per-block regions.
        unsafe impl Send for SyncPtr {}
        // SAFETY: as above — shared only for disjoint writes while the
        // borrow is live.
        unsafe impl Sync for SyncPtr {}
        let outp = SyncPtr(out.as_mut_ptr());
        pool::run_blocks(nblocks, &|blk| {
            let rb = blk * MC;
            let rend = (rb + MC).min(rows);
            // SAFETY: block `blk` exclusively owns out rows rb..rend;
            // the regions of distinct blocks are disjoint and `out` is
            // not resized while the pool runs (caller blocks in
            // run_blocks until every block completes).
            let ob = unsafe {
                std::slice::from_raw_parts_mut(outp.0.add(rb * d_out), (rend - rb) * d_out)
            };
            self.gemm_block(path, &x[rb * d_in..rend * d_in], ob);
        });
    }

    /// Blocked GEMM with a fused epilogue: each `MC`-row block finishes
    /// **all** `NR` column panels (full output rows complete while
    /// resident in L1/L2 — [`Self::gemm_block`] is already panel-outer
    /// *within* a block), then the [`Epilogue`] is applied to those hot
    /// rows and only the int8 result is written to `out`.  The i32
    /// accumulator tile lives in a per-worker thread-local and never
    /// round-trips through the caller's memory — that is the
    /// bytes-moved win `aie_sim::bytes` models.
    ///
    /// Bit-exact with `gemm_into` followed by the standalone
    /// requant/residual/LayerNorm sweeps, on both dispatch paths.
    pub fn gemm_fused_into(&self, x: &[i8], ep: &Epilogue<'_>, out: &mut Vec<i8>) {
        self.gemm_fused_into_with_path(simd::active(), x, ep, out);
    }

    /// [`Self::gemm_fused_into`] with an explicit dispatch path.
    pub fn gemm_fused_into_with_path(
        &self,
        path: SimdPath,
        x: &[i8],
        ep: &Epilogue<'_>,
        out: &mut Vec<i8>,
    ) {
        assert!(x.len() % self.d_in == 0, "x is not a whole number of d_in rows");
        let path = simd::require(path);
        let rows = x.len() / self.d_in;
        ep.check(rows, self.d_out);
        resize_for_overwrite(out, rows * self.d_out);
        if rows == 0 {
            return;
        }
        let (d_in, d_out) = (self.d_in, self.d_out);
        let nblocks = rows.div_ceil(MC);
        struct SyncPtr(*mut i8);
        // SAFETY: same disjoint-write argument as the `gemm_into`
        // SyncPtr — the `out` borrow outlives the fan-out and blocks
        // write disjoint row regions.
        unsafe impl Send for SyncPtr {}
        // SAFETY: as above — shared only for disjoint writes while the
        // borrow is live.
        unsafe impl Sync for SyncPtr {}
        let outp = SyncPtr(out.as_mut_ptr());
        pool::run_blocks(nblocks, &|blk| {
            let rb = blk * MC;
            let rend = (rb + MC).min(rows);
            // SAFETY: same disjoint-region argument as
            // `gemm_into_with_path` — block `blk` exclusively owns out
            // rows rb..rend and `out` is not resized while the pool
            // runs.
            let db = unsafe {
                std::slice::from_raw_parts_mut(outp.0.add(rb * d_out), (rend - rb) * d_out)
            };
            BLOCK_ACC.with(|cell| {
                let mut acc = cell.take();
                resize_for_overwrite(&mut acc, (rend - rb) * d_out);
                self.gemm_block(path, &x[rb * d_in..rend * d_in], &mut acc);
                ep.apply_block(path, &mut acc, d_out, rb, db);
                cell.set(acc);
            });
        });
    }

    /// One ≤`MC`-row block: panel loop → row loop → k loop.  `out` is
    /// exactly `(x.len()/d_in) · d_out`.
    fn gemm_block(&self, path: SimdPath, x: &[i8], out: &mut [i32]) {
        let (d_in, d_out) = (self.d_in, self.d_out);
        match path {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `path == Avx2` only passes `simd::require` when
            // runtime detection confirmed AVX2 support.
            SimdPath::Avx2 => unsafe { avx2::gemm_block(&self.packed, d_in, d_out, x, out) },
            _ => {
                let rows = x.len() / d_in;
                for (p, panel) in self.packed.chunks_exact(d_in * NR).enumerate() {
                    let o0 = p * NR;
                    let take = NR.min(d_out - o0);
                    for r in 0..rows {
                        let xrow = &x[r * d_in..(r + 1) * d_in];
                        let mut acc = [0i32; NR];
                        for (k, &xv) in xrow.iter().enumerate() {
                            let stripe = &panel[k * NR..(k + 1) * NR];
                            let xv = i32::from(xv);
                            for (a, &wv) in acc.iter_mut().zip(stripe) {
                                *a += xv * i32::from(wv);
                            }
                        }
                        out[r * d_out + o0..r * d_out + o0 + take].copy_from_slice(&acc[..take]);
                    }
                }
            }
        }
    }
}

thread_local! {
    /// Per-worker i32 accumulator tile of [`PackedGemm::gemm_fused_into`]
    /// (one ≤`MC`-row block).  Thread-local so pool workers never
    /// contend, retained across calls so the hot loop allocates only on
    /// the first block a thread processes.
    static BLOCK_ACC: Cell<Vec<i32>> = const { Cell::new(Vec::new()) };
}

/// A·Bᵀ for two row-major int8 operands: `a` is `(m, kd)`, `b` is
/// `(n, kd)`, `out` (len `m·n`) gets `out[i][j] = Σ_t a[i][t]·b[j][t]`.
///
/// This is the QK^T stage: both sides are activations, so there is no
/// pack step — instead four B rows are register-blocked per pass, so
/// each A row is loaded once per four output columns.  Bit-exact with
/// `dot_i8` per cell on both dispatch paths.
pub fn gemm_nt_into(a: &[i8], b: &[i8], m: usize, n: usize, kd: usize, out: &mut [i32]) {
    gemm_nt_bounded_into(a, b, m, n, n, kd, out);
}

/// Column-bounded A·Bᵀ: only the first `n_active` output columns are
/// computed (`b` holds exactly the `n_active` active rows — for QK^T,
/// the valid keys); columns `n_active..n` of every output row are
/// **zeroed**.  This is how the valid-length attention path skips
/// pad-key MACs entirely while keeping the `(m, n)` tile stride of the
/// dense layout.  `n_active == n` is exactly [`gemm_nt_into`].
/// Bit-exact with `dot_i8` per active cell.
pub fn gemm_nt_bounded_into(
    a: &[i8],
    b: &[i8],
    m: usize,
    n: usize,
    n_active: usize,
    kd: usize,
    out: &mut [i32],
) {
    gemm_nt_bounded_into_with_path(simd::active(), a, b, m, n, n_active, kd, out);
}

/// [`gemm_nt_bounded_into`] with an explicit dispatch path.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_bounded_into_with_path(
    path: SimdPath,
    a: &[i8],
    b: &[i8],
    m: usize,
    n: usize,
    n_active: usize,
    kd: usize,
    out: &mut [i32],
) {
    assert!(m > 0 && n > 0 && kd > 0, "empty GEMM operand");
    assert!((1..=n).contains(&n_active), "n_active must be in 1..=n");
    assert_eq!(a.len(), m * kd, "a is not (m, kd)");
    assert_eq!(b.len(), n_active * kd, "b is not (n_active, kd)");
    assert_eq!(out.len(), m * n, "out is not (m, n)");
    match simd::require(path) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: require() verified AVX2 is available.
        SimdPath::Avx2 => unsafe { avx2::gemm_nt_bounded(a, b, m, n, n_active, kd, out) },
        _ => nt_bounded_scalar(a, b, n, n_active, kd, out),
    }
}

fn nt_bounded_scalar(a: &[i8], b: &[i8], n: usize, n_active: usize, kd: usize, out: &mut [i32]) {
    for (arow, orow) in a.chunks_exact(kd).zip(out.chunks_exact_mut(n)) {
        orow[n_active..].fill(0);
        let orow = &mut orow[..n_active];
        let mut j = 0usize;
        while j + 4 <= n_active {
            let b0 = &b[j * kd..(j + 1) * kd];
            let b1 = &b[(j + 1) * kd..(j + 2) * kd];
            let b2 = &b[(j + 2) * kd..(j + 3) * kd];
            let b3 = &b[(j + 3) * kd..(j + 4) * kd];
            let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
            for (t, &av) in arow.iter().enumerate() {
                let av = i32::from(av);
                s0 += av * i32::from(b0[t]);
                s1 += av * i32::from(b1[t]);
                s2 += av * i32::from(b2[t]);
                s3 += av * i32::from(b3[t]);
            }
            orow[j] = s0;
            orow[j + 1] = s1;
            orow[j + 2] = s2;
            orow[j + 3] = s3;
            j += 4;
        }
        for (o, brow) in orow[j..].iter_mut().zip(b[j * kd..].chunks_exact(kd)) {
            *o = dot_i8(arow, brow);
        }
    }
}

/// The probability mix p̂·V: `p` is row-major `(m, c)` i32, `v` is
/// `(c, dv)` int8, `out` (len `m·dv`) gets `out[i][:] = Σ_j p[i][j]·v[j][:]`.
///
/// Rows with `p̂ = 0` (clamped HCCS tails, frequent on the i8 path) are
/// skipped — the sparsity shortcut the old inline attention loop had
/// (preserved on both dispatch paths).
pub fn gemm_pv_into(p: &[i32], v: &[i8], m: usize, c: usize, dv: usize, out: &mut [i32]) {
    gemm_pv_bounded_into(p, v, m, c, c, dv, out);
}

/// Column-bounded p̂·V: only the first `c_active` probabilities of each
/// `(m, c)`-strided p̂ row enter the mix (`v` holds exactly the
/// `c_active` active value rows — the valid keys), so pad-key MACs are
/// skipped structurally rather than relying on the `p̂ = 0` shortcut to
/// scan past them.  `c_active == c` is exactly [`gemm_pv_into`].
pub fn gemm_pv_bounded_into(
    p: &[i32],
    v: &[i8],
    m: usize,
    c: usize,
    c_active: usize,
    dv: usize,
    out: &mut [i32],
) {
    gemm_pv_bounded_into_with_path(simd::active(), p, v, m, c, c_active, dv, out);
}

/// [`gemm_pv_bounded_into`] with an explicit dispatch path.
#[allow(clippy::too_many_arguments)]
pub fn gemm_pv_bounded_into_with_path(
    path: SimdPath,
    p: &[i32],
    v: &[i8],
    m: usize,
    c: usize,
    c_active: usize,
    dv: usize,
    out: &mut [i32],
) {
    assert!(m > 0 && c > 0 && dv > 0, "empty GEMM operand");
    assert!((1..=c).contains(&c_active), "c_active must be in 1..=c");
    assert_eq!(p.len(), m * c, "p is not (m, c)");
    assert_eq!(v.len(), c_active * dv, "v is not (c_active, dv)");
    assert_eq!(out.len(), m * dv, "out is not (m, dv)");
    match simd::require(path) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: require() verified AVX2 is available.
        SimdPath::Avx2 => unsafe { avx2::gemm_pv_bounded(p, v, c, c_active, dv, out) },
        _ => pv_bounded_scalar(p, v, c, c_active, dv, out),
    }
}

fn pv_bounded_scalar(p: &[i32], v: &[i8], c: usize, c_active: usize, dv: usize, out: &mut [i32]) {
    for (prow, orow) in p.chunks_exact(c).zip(out.chunks_exact_mut(dv)) {
        orow.fill(0);
        for (j, &pv) in prow[..c_active].iter().enumerate() {
            if pv == 0 {
                continue;
            }
            let vrow = &v[j * dv..(j + 1) * dv];
            for (o, &vv) in orow.iter_mut().zip(vrow) {
                *o += pv * i32::from(vv);
            }
        }
    }
}

/// Explicit AVX2 int8/int16 kernels.  Exactness argument per kernel:
/// int8 operands sign-extend to i16, `_mm256_madd_epi16` products are
/// ≤ 127² = 16129 so pair sums fit i16×i16→i32 exactly (madd saturates
/// only at both-pairs-(-32768)², impossible here), and i32 accumulation
/// never overflows under the repo's shape limits — so any lane/reduce
/// order matches the scalar loops bit for bit.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::NR;
    use std::arch::x86_64::*;

    /// Load two consecutive k-stripes (16 contiguous int8) and
    /// interleave them into madd pair order:
    /// i16 lane `2j` = `w[k][j]`, lane `2j+1` = `w[k+1][j]`.
    ///
    /// SAFETY: caller guarantees 16 readable bytes at `ptr` and AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn load_wpair(ptr: *const i8) -> __m256i {
        // SAFETY: caller contract above — 16 readable bytes at `ptr`.
        let v = unsafe { _mm_loadu_si128(ptr as *const __m128i) };
        let lo = _mm_cvtepi8_epi16(v); // w[k][0..8] as i16
        let hi = _mm_cvtepi8_epi16(_mm_srli_si128::<8>(v)); // w[k+1][0..8]
        _mm256_set_m128i(_mm_unpackhi_epi16(lo, hi), _mm_unpacklo_epi16(lo, hi))
    }

    /// Final odd-k stripe: only 8 bytes exist at `ptr` (a 16-byte load
    /// would read past the packed buffer), partner lanes are zero.
    ///
    /// SAFETY: caller guarantees 8 readable bytes at `ptr` and AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn load_wlast(ptr: *const i8) -> __m256i {
        // SAFETY: caller contract above — 8 readable bytes at `ptr`.
        let v = unsafe { _mm_loadl_epi64(ptr as *const __m128i) };
        let lo = _mm_cvtepi8_epi16(v);
        let z = _mm_setzero_si128();
        _mm256_set_m128i(_mm_unpackhi_epi16(lo, z), _mm_unpacklo_epi16(lo, z))
    }

    /// Broadcast the activation pair `(x[k], x[k+1])` into every i32
    /// lane (low i16 = `x[k]`, high i16 = `x[k+1]`), matching
    /// [`load_wpair`]'s interleave.
    ///
    /// SAFETY: requires AVX2 only; indexing is slice-bounds-checked.
    #[target_feature(enable = "avx2")]
    unsafe fn xpair(x: &[i8], k: usize) -> __m256i {
        let lo = x[k] as i16 as u16 as u32;
        let hi = x[k + 1] as i16 as u16 as u32;
        _mm256_set1_epi32(((hi << 16) | lo) as i32)
    }

    /// Broadcast a lone activation (partner i16 lane zero, matching
    /// [`load_wlast`]).
    ///
    /// SAFETY: requires AVX2 only; indexing is slice-bounds-checked.
    #[target_feature(enable = "avx2")]
    unsafe fn xlast(x: &[i8], k: usize) -> __m256i {
        _mm256_set1_epi32(x[k] as i16 as u16 as u32 as i32)
    }

    /// Store the 8 accumulator lanes into `out[..take]`.
    ///
    /// SAFETY: caller guarantees `out.len() >= take` and AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn store_acc(acc: __m256i, out: &mut [i32], take: usize) {
        if take == NR {
            // SAFETY: take == NR ⇒ out has >= NR writable i32 (caller
            // contract), exactly the 32 bytes this store writes.
            unsafe { _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, acc) };
        } else {
            let mut tmp = [0i32; NR];
            // SAFETY: tmp is exactly NR i32 — 32 writable bytes.
            unsafe { _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, acc) };
            out[..take].copy_from_slice(&tmp[..take]);
        }
    }

    /// AVX2 packed-GEMM block: same loop nest as the scalar
    /// `gemm_block`, with the `NR`-lane k-loop fused two k's at a time
    /// through `madd_epi16`, and four rows register-blocked so each
    /// weight-pair load is reused 4×.
    ///
    /// SAFETY: requires AVX2; `packed` is whole panels of `d_in·NR`,
    /// `x` is whole `d_in` rows, `out` is `rows·d_out`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_block(packed: &[i8], d_in: usize, d_out: usize, x: &[i8], out: &mut [i32]) {
        let rows = x.len() / d_in;
        for (p, panel) in packed.chunks_exact(d_in * NR).enumerate() {
            let o0 = p * NR;
            let take = NR.min(d_out - o0);
            let mut r = 0usize;
            while r + 4 <= rows {
                let x0 = &x[r * d_in..(r + 1) * d_in];
                let x1 = &x[(r + 1) * d_in..(r + 2) * d_in];
                let x2 = &x[(r + 2) * d_in..(r + 3) * d_in];
                let x3 = &x[(r + 3) * d_in..(r + 4) * d_in];
                let mut a0 = _mm256_setzero_si256();
                let mut a1 = _mm256_setzero_si256();
                let mut a2 = _mm256_setzero_si256();
                let mut a3 = _mm256_setzero_si256();
                let mut k = 0usize;
                while k + 2 <= d_in {
                    // SAFETY: k + 2 <= d_in keeps the 16-byte pair load
                    // in bounds of the d_in·NR panel; xpair reads
                    // x*[k..k+2] via checked indexing.
                    unsafe {
                        let w = load_wpair(panel.as_ptr().add(k * NR));
                        a0 = _mm256_add_epi32(a0, _mm256_madd_epi16(w, xpair(x0, k)));
                        a1 = _mm256_add_epi32(a1, _mm256_madd_epi16(w, xpair(x1, k)));
                        a2 = _mm256_add_epi32(a2, _mm256_madd_epi16(w, xpair(x2, k)));
                        a3 = _mm256_add_epi32(a3, _mm256_madd_epi16(w, xpair(x3, k)));
                    }
                    k += 2;
                }
                if k < d_in {
                    // SAFETY: the final odd stripe leaves exactly NR = 8
                    // panel bytes at offset k·NR — load_wlast reads 8.
                    unsafe {
                        let w = load_wlast(panel.as_ptr().add(k * NR));
                        a0 = _mm256_add_epi32(a0, _mm256_madd_epi16(w, xlast(x0, k)));
                        a1 = _mm256_add_epi32(a1, _mm256_madd_epi16(w, xlast(x1, k)));
                        a2 = _mm256_add_epi32(a2, _mm256_madd_epi16(w, xlast(x2, k)));
                        a3 = _mm256_add_epi32(a3, _mm256_madd_epi16(w, xlast(x3, k)));
                    }
                }
                // SAFETY: each destination row slice holds >= take
                // writable i32 (out is rows·d_out and o0 + take <= d_out).
                unsafe {
                    store_acc(a0, &mut out[r * d_out + o0..], take);
                    store_acc(a1, &mut out[(r + 1) * d_out + o0..], take);
                    store_acc(a2, &mut out[(r + 2) * d_out + o0..], take);
                    store_acc(a3, &mut out[(r + 3) * d_out + o0..], take);
                }
                r += 4;
            }
            while r < rows {
                let xrow = &x[r * d_in..(r + 1) * d_in];
                let mut acc = _mm256_setzero_si256();
                let mut k = 0usize;
                while k + 2 <= d_in {
                    // SAFETY: as in the 4-row loop — k + 2 <= d_in
                    // bounds the 16-byte pair load inside the panel.
                    unsafe {
                        let w = load_wpair(panel.as_ptr().add(k * NR));
                        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(w, xpair(xrow, k)));
                    }
                    k += 2;
                }
                if k < d_in {
                    // SAFETY: exactly NR = 8 panel bytes remain at
                    // offset k·NR — load_wlast reads 8.
                    unsafe {
                        let w = load_wlast(panel.as_ptr().add(k * NR));
                        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(w, xlast(xrow, k)));
                    }
                }
                // SAFETY: the destination row slice holds >= take writable i32.
                unsafe { store_acc(acc, &mut out[r * d_out + o0..], take) };
                r += 1;
            }
        }
    }

    /// Horizontal i32 sum of all 8 lanes.
    ///
    /// SAFETY: requires AVX2 only — pure register math, no memory.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(v: __m256i) -> i32 {
        let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
        let s = _mm_hadd_epi32(s, s);
        let s = _mm_hadd_epi32(s, s);
        _mm_cvtsi128_si32(s)
    }

    /// One A-row × one B-row dot, 16 int8 per madd step.
    ///
    /// SAFETY: requires AVX2; `a` and `b` hold at least `kd` bytes.
    #[target_feature(enable = "avx2")]
    unsafe fn dot1(a: &[i8], b: &[i8], kd: usize) -> i32 {
        let mut acc = _mm256_setzero_si256();
        let mut t = 0usize;
        while t + 16 <= kd {
            // SAFETY: t + 16 <= kd <= a.len(), b.len() keeps both
            // 16-byte loads in bounds.
            unsafe {
                let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(t) as *const __m128i));
                let bv = _mm256_cvtepi8_epi16(_mm_loadu_si128(b.as_ptr().add(t) as *const __m128i));
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
            }
            t += 16;
        }
        // SAFETY: hsum is register-only; AVX2 per the caller contract.
        let mut s = unsafe { hsum_epi32(acc) };
        while t < kd {
            s += i32::from(a[t]) * i32::from(b[t]);
            t += 1;
        }
        s
    }

    /// AVX2 A·Bᵀ with the same 4-B-row register blocking as the scalar
    /// kernel (each 16-wide A load serves four madd streams).
    ///
    /// SAFETY: requires AVX2; shapes pre-validated by the dispatcher.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_nt_bounded(
        a: &[i8],
        b: &[i8],
        m: usize,
        n: usize,
        n_active: usize,
        kd: usize,
        out: &mut [i32],
    ) {
        for i in 0..m {
            let arow = &a[i * kd..(i + 1) * kd];
            let orow = &mut out[i * n..(i + 1) * n];
            orow[n_active..].fill(0);
            let mut j = 0usize;
            while j + 4 <= n_active {
                let b0 = &b[j * kd..(j + 1) * kd];
                let b1 = &b[(j + 1) * kd..(j + 2) * kd];
                let b2 = &b[(j + 2) * kd..(j + 3) * kd];
                let b3 = &b[(j + 3) * kd..(j + 4) * kd];
                let mut a0 = _mm256_setzero_si256();
                let mut a1 = _mm256_setzero_si256();
                let mut a2 = _mm256_setzero_si256();
                let mut a3 = _mm256_setzero_si256();
                let mut t = 0usize;
                while t + 16 <= kd {
                    // SAFETY: t + 16 <= kd bounds all five 16-byte
                    // loads inside their kd-length rows.
                    unsafe {
                        let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                            arow.as_ptr().add(t) as *const __m128i
                        ));
                        let l0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                            b0.as_ptr().add(t) as *const __m128i
                        ));
                        let l1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                            b1.as_ptr().add(t) as *const __m128i
                        ));
                        let l2 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                            b2.as_ptr().add(t) as *const __m128i
                        ));
                        let l3 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                            b3.as_ptr().add(t) as *const __m128i
                        ));
                        a0 = _mm256_add_epi32(a0, _mm256_madd_epi16(av, l0));
                        a1 = _mm256_add_epi32(a1, _mm256_madd_epi16(av, l1));
                        a2 = _mm256_add_epi32(a2, _mm256_madd_epi16(av, l2));
                        a3 = _mm256_add_epi32(a3, _mm256_madd_epi16(av, l3));
                    }
                    t += 16;
                }
                // SAFETY: hsum is register-only; AVX2 per the caller
                // contract.
                let (mut s0, mut s1, mut s2, mut s3) = unsafe {
                    (hsum_epi32(a0), hsum_epi32(a1), hsum_epi32(a2), hsum_epi32(a3))
                };
                while t < kd {
                    let av = i32::from(arow[t]);
                    s0 += av * i32::from(b0[t]);
                    s1 += av * i32::from(b1[t]);
                    s2 += av * i32::from(b2[t]);
                    s3 += av * i32::from(b3[t]);
                    t += 1;
                }
                orow[j] = s0;
                orow[j + 1] = s1;
                orow[j + 2] = s2;
                orow[j + 3] = s3;
                j += 4;
            }
            while j < n_active {
                // SAFETY: both row slices are exactly kd bytes — dot1's
                // length contract — and AVX2 holds per the caller.
                orow[j] = unsafe { dot1(arow, &b[j * kd..(j + 1) * kd], kd) };
                j += 1;
            }
        }
    }

    /// AVX2 p̂·V mix: broadcast each nonzero p̂ and FMA it against the
    /// value row 8 i32 lanes at a time (`p̂·v ≤ 32767·127` — exact in
    /// `mullo_epi32`).  Keeps the scalar kernel's `p̂ = 0` shortcut.
    ///
    /// SAFETY: requires AVX2; shapes pre-validated by the dispatcher.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_pv_bounded(
        p: &[i32],
        v: &[i8],
        c: usize,
        c_active: usize,
        dv: usize,
        out: &mut [i32],
    ) {
        for (prow, orow) in p.chunks_exact(c).zip(out.chunks_exact_mut(dv)) {
            orow.fill(0);
            for (j, &pv) in prow[..c_active].iter().enumerate() {
                if pv == 0 {
                    continue;
                }
                let vrow = &v[j * dv..(j + 1) * dv];
                let pvv = _mm256_set1_epi32(pv);
                let mut t = 0usize;
                while t + 8 <= dv {
                    // SAFETY: t + 8 <= dv bounds the 8-byte value load
                    // and the 32-byte accumulator load/store inside
                    // their dv-length rows.
                    unsafe {
                        let vv = _mm256_cvtepi8_epi32(_mm_loadl_epi64(
                            vrow.as_ptr().add(t) as *const __m128i
                        ));
                        let cur = _mm256_loadu_si256(orow.as_ptr().add(t) as *const __m256i);
                        _mm256_storeu_si256(
                            orow.as_mut_ptr().add(t) as *mut __m256i,
                            _mm256_add_epi32(cur, _mm256_mullo_epi32(pvv, vv)),
                        );
                    }
                    t += 8;
                }
                while t < dv {
                    orow[t] += pv * i32::from(vrow[t]);
                    t += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn rand_i8(rng: &mut Xoshiro256, n: usize) -> Vec<i8> {
        (0..n).map(|_| rng.i8()).collect()
    }

    #[test]
    fn packed_matches_scalar_on_ragged_shapes() {
        let mut rng = Xoshiro256::new(7);
        // Includes panel-exact, sub-panel, and ragged d_out; ragged d_in;
        // 1-row and multi-block row counts.
        for (rows, d_in, d_out) in [
            (1usize, 1usize, 1usize),
            (1, 7, 8),
            (3, 8, 5),
            (4, 13, 17),
            (64, 64, 64),
            (65, 32, 24),
            (130, 5, 9),
        ] {
            let x = rand_i8(&mut rng, rows * d_in);
            let w = rand_i8(&mut rng, d_out * d_in);
            let packed = PackedGemm::pack(&w, d_out, d_in);
            assert_eq!(packed.d_in(), d_in);
            assert_eq!(packed.d_out(), d_out);
            let (mut got, mut want) = (Vec::new(), Vec::new());
            packed.gemm_into(&x, &mut got);
            matmul_i8_ref(&x, d_in, &w, d_out, &mut want);
            assert_eq!(got, want, "rows={rows} d_in={d_in} d_out={d_out}");
        }
    }

    #[test]
    fn packed_paths_agree_on_ragged_shapes() {
        if !simd::avx2_available() {
            return; // AVX2 leg covered on x86-64 CI
        }
        let mut rng = Xoshiro256::new(29);
        for (rows, d_in, d_out) in [
            (1usize, 1usize, 1usize),
            (1, 2, 8),
            (2, 3, 8), // odd-k tail hits load_wlast
            (5, 16, 9),
            (4, 13, 17),
            (67, 31, 24),
        ] {
            let x = rand_i8(&mut rng, rows * d_in);
            let w = rand_i8(&mut rng, d_out * d_in);
            let packed = PackedGemm::pack(&w, d_out, d_in);
            let (mut simd_out, mut scalar_out) = (Vec::new(), Vec::new());
            packed.gemm_into_with_path(SimdPath::Avx2, &x, &mut simd_out);
            packed.gemm_into_with_path(SimdPath::Scalar, &x, &mut scalar_out);
            assert_eq!(simd_out, scalar_out, "rows={rows} d_in={d_in} d_out={d_out}");
        }
    }

    #[test]
    fn nt_and_pv_paths_agree() {
        if !simd::avx2_available() {
            return;
        }
        let mut rng = Xoshiro256::new(31);
        let (m, n, kd) = (5usize, 11usize, 35usize); // 16-chunk + tail
        let a = rand_i8(&mut rng, m * kd);
        let b = rand_i8(&mut rng, n * kd);
        for n_active in [1usize, 4, 7, 11] {
            let (mut x, mut y) = (vec![3i32; m * n], vec![4i32; m * n]);
            gemm_nt_bounded_into_with_path(SimdPath::Avx2, &a, &b[..n_active * kd], m, n, n_active, kd, &mut x);
            gemm_nt_bounded_into_with_path(SimdPath::Scalar, &a, &b[..n_active * kd], m, n, n_active, kd, &mut y);
            assert_eq!(x, y, "nt n_active={n_active}");
        }
        let (c, dv) = (9usize, 13usize); // 8-chunk + tail
        let p: Vec<i32> = (0..m * c).map(|_| rng.range_i64(0, 32767) as i32).collect();
        let v = rand_i8(&mut rng, c * dv);
        for c_active in [1usize, 5, 9] {
            let (mut x, mut y) = (vec![3i32; m * dv], vec![4i32; m * dv]);
            gemm_pv_bounded_into_with_path(SimdPath::Avx2, &p, &v[..c_active * dv], m, c, c_active, dv, &mut x);
            gemm_pv_bounded_into_with_path(SimdPath::Scalar, &p, &v[..c_active * dv], m, c, c_active, dv, &mut y);
            assert_eq!(x, y, "pv c_active={c_active}");
        }
    }

    #[test]
    fn gemm_into_reuses_caller_scratch() {
        let mut rng = Xoshiro256::new(11);
        let w = rand_i8(&mut rng, 6 * 4);
        let packed = PackedGemm::pack(&w, 6, 4);
        let mut out = vec![99i32; 64]; // stale, over-sized scratch
        let x = rand_i8(&mut rng, 2 * 4);
        packed.gemm_into(&x, &mut out);
        assert_eq!(out.len(), 2 * 6);
        let mut want = Vec::new();
        matmul_i8_ref(&x, 4, &w, 6, &mut want);
        assert_eq!(out, want);
    }

    #[test]
    fn resize_for_overwrite_truncates_and_keeps_prefix() {
        let mut v: Vec<i32> = vec![1, 2, 3];
        resize_for_overwrite(&mut v, 2);
        assert_eq!(v, vec![1, 2]);
        resize_for_overwrite(&mut v, 5);
        assert_eq!(v.len(), 5);
        assert_eq!(&v[..2], &[1, 2]);
        // The tail is POISON in debug builds / arbitrary in release —
        // the write-all contract means callers never read it.
        resize_for_overwrite(&mut v, 0);
        assert!(v.is_empty());
    }

    #[test]
    fn fused_epilogue_matches_unfused_composition() {
        use crate::linalg::epilogue::{layernorm_rows_with_path, requant_with_path};
        let mut rng = Xoshiro256::new(37);
        // Sub-block, ragged, and multi-block (pool-spanning) row counts.
        for (rows, d_in, d_out) in [(1usize, 4usize, 4usize), (5, 13, 17), (70, 32, 24), (130, 8, 8)]
        {
            let x = rand_i8(&mut rng, rows * d_in);
            let w = rand_i8(&mut rng, d_out * d_in);
            let packed = PackedGemm::pack(&w, d_out, d_in);
            let residual: Vec<i8> = (0..rows * d_out).map(|_| rng.i8()).collect();
            let gamma: Vec<i8> = (0..d_out).map(|_| rng.range_i64(48, 80) as i8).collect();
            let beta: Vec<i8> = (0..d_out).map(|_| rng.i8()).collect();
            let div = 3;
            for path in [SimdPath::Scalar, SimdPath::Avx2] {
                if path == SimdPath::Avx2 && !simd::avx2_available() {
                    continue;
                }
                let label = format!("rows={rows} d_in={d_in} d_out={d_out} path={path:?}");
                let mut acc = Vec::new();
                packed.gemm_into_with_path(path, &x, &mut acc);
                let mut got = vec![9i8; 3]; // stale scratch must be reshaped
                // Requant.
                let mut want = Vec::new();
                requant_with_path(path, &acc, div, &mut want);
                packed.gemm_fused_into_with_path(path, &x, &Epilogue::Requant { div }, &mut got);
                assert_eq!(got, want, "requant {label}");
                // Requant + ReLU.
                let want_relu: Vec<i8> = want.iter().map(|&v| v.max(0)).collect();
                packed.gemm_fused_into_with_path(path, &x, &Epilogue::RequantRelu { div }, &mut got);
                assert_eq!(got, want_relu, "relu {label}");
                // Requant + residual + LayerNorm.
                let x32: Vec<i32> = want
                    .iter()
                    .zip(&residual)
                    .map(|(&q, &r)| i32::from(r) + i32::from(q))
                    .collect();
                let mut want_ln = Vec::new();
                layernorm_rows_with_path(path, &x32, d_out, &gamma, &beta, &mut want_ln);
                let ep = Epilogue::RequantResidualLn {
                    div,
                    residual: &residual,
                    gamma: &gamma,
                    beta: &beta,
                };
                packed.gemm_fused_into_with_path(path, &x, &ep, &mut got);
                assert_eq!(got, want_ln, "residual+ln {label}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "rows × d_out")]
    fn fused_rejects_residual_shape_mismatch() {
        let packed = PackedGemm::pack(&[1i8; 12], 3, 4);
        let ep = Epilogue::RequantResidualLn {
            div: 1,
            residual: &[0i8; 5], // should be 2 rows × 3 = 6
            gamma: &[64i8; 3],
            beta: &[0i8; 3],
        };
        packed.gemm_fused_into(&[0i8; 8], &ep, &mut Vec::new());
    }

    #[test]
    fn nt_matches_per_cell_dots() {
        let mut rng = Xoshiro256::new(3);
        for (m, n, kd) in [(1usize, 1usize, 1usize), (2, 3, 5), (4, 7, 16), (5, 9, 33)] {
            let a = rand_i8(&mut rng, m * kd);
            let b = rand_i8(&mut rng, n * kd);
            let mut out = vec![0i32; m * n];
            gemm_nt_into(&a, &b, m, n, kd, &mut out);
            for i in 0..m {
                for j in 0..n {
                    let want = dot_i8(&a[i * kd..(i + 1) * kd], &b[j * kd..(j + 1) * kd]);
                    assert_eq!(out[i * n + j], want, "m={m} n={n} kd={kd} cell ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn pv_matches_naive_mix_and_skips_zero_rows() {
        let mut rng = Xoshiro256::new(5);
        let (m, c, dv) = (3usize, 8usize, 5usize);
        let mut p: Vec<i32> = (0..m * c).map(|_| rng.range_i64(0, 300) as i32).collect();
        p[1] = 0;
        p[c + 3] = 0;
        let v = rand_i8(&mut rng, c * dv);
        let mut out = vec![7i32; m * dv];
        gemm_pv_into(&p, &v, m, c, dv, &mut out);
        for i in 0..m {
            for t in 0..dv {
                let want: i32 = (0..c).map(|j| p[i * c + j] * i32::from(v[j * dv + t])).sum();
                assert_eq!(out[i * dv + t], want, "cell ({i},{t})");
            }
        }
    }

    #[test]
    fn nt_bounded_computes_active_columns_and_zeroes_pads() {
        let mut rng = Xoshiro256::new(13);
        let (m, n, kd) = (3usize, 9usize, 7usize);
        let a = rand_i8(&mut rng, m * kd);
        let full_b = rand_i8(&mut rng, n * kd);
        for n_active in [1usize, 4, 8, 9] {
            let b = &full_b[..n_active * kd];
            let mut out = vec![77i32; m * n]; // stale scratch must be overwritten
            gemm_nt_bounded_into(&a, b, m, n, n_active, kd, &mut out);
            for i in 0..m {
                for j in 0..n_active {
                    let want = dot_i8(&a[i * kd..(i + 1) * kd], &b[j * kd..(j + 1) * kd]);
                    assert_eq!(out[i * n + j], want, "n_active={n_active} cell ({i},{j})");
                }
                assert!(
                    out[i * n + n_active..(i + 1) * n].iter().all(|&v| v == 0),
                    "pad columns not zeroed at n_active={n_active}, row {i}"
                );
            }
        }
        // Full width is exactly gemm_nt_into.
        let mut bounded = vec![0i32; m * n];
        let mut dense = vec![0i32; m * n];
        gemm_nt_bounded_into(&a, &full_b, m, n, n, kd, &mut bounded);
        gemm_nt_into(&a, &full_b, m, n, kd, &mut dense);
        assert_eq!(bounded, dense);
    }

    #[test]
    fn pv_bounded_ignores_pad_columns() {
        let mut rng = Xoshiro256::new(17);
        let (m, c, dv) = (2usize, 8usize, 3usize);
        // Nonzero garbage in the pad columns must not leak into the mix.
        let p: Vec<i32> = (0..m * c).map(|_| rng.range_i64(-50, 300) as i32).collect();
        let v = rand_i8(&mut rng, c * dv);
        for c_active in [1usize, 5, 8] {
            let mut out = vec![9i32; m * dv];
            gemm_pv_bounded_into(&p, &v[..c_active * dv], m, c, c_active, dv, &mut out);
            for i in 0..m {
                for t in 0..dv {
                    let want: i32 = (0..c_active)
                        .map(|j| p[i * c + j] * i32::from(v[j * dv + t]))
                        .sum();
                    assert_eq!(out[i * dv + t], want, "c_active={c_active} cell ({i},{t})");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "n_active")]
    fn nt_bounded_rejects_zero_active() {
        gemm_nt_bounded_into(&[0i8; 4], &[0i8; 4], 1, 2, 0, 4, &mut [0i32; 2]);
    }

    #[test]
    fn dot_matches_hand_computation() {
        assert_eq!(dot_i8(&[1, 2, 3], &[4, -5, 6]), 4 - 10 + 18);
        assert_eq!(dot_i8(&[], &[]), 0);
    }

    #[test]
    #[should_panic(expected = "whole number")]
    fn gemm_rejects_ragged_input() {
        let packed = PackedGemm::pack(&[1i8; 12], 3, 4);
        packed.gemm_into(&[0i8; 5], &mut Vec::new());
    }

    #[test]
    #[should_panic(expected = "not (m, kd)")]
    fn nt_rejects_shape_mismatch() {
        gemm_nt_into(&[0i8; 5], &[0i8; 8], 2, 2, 4, &mut [0i32; 4]);
    }
}
