//! Fused GEMM epilogues: requant → residual add → integer LayerNorm
//! applied to an output row block while it is still L1/L2-resident.
//!
//! Before this module, every encoder/decoder layer did
//! GEMM → write full i32 tile → requant sweep → residual sweep →
//! LayerNorm sweep — three to four extra full-tile memory passes per
//! op on a datapath that is no longer MAC-bound (the SOLE observation,
//! applied to this integer stack).  [`Epilogue`] is the fusion hook:
//! [`crate::linalg::PackedGemm::gemm_fused_into`] finishes all NR
//! column panels of one MC row block and then invokes the epilogue on
//! those hot rows, so the i32 accumulator tile never round-trips
//! through memory.  It is an **enum, not a closure**, so the AVX2
//! kernel stays monomorphic — no indirect call in the hot loop.
//!
//! The normalization/requant primitives themselves live here too
//! (moved from `model/norm.rs`, which re-exports them): the standalone
//! [`requant`] / [`layernorm_rows`] sweeps used by the call sites that
//! stay unfused (embeddings, classifier pooling, attention context)
//! now dispatch scalar vs AVX2 through [`crate::simd::active`] like
//! every other kernel, with `*_with_path` pins for the differential
//! harness.
//!
//! ## Exactness contract
//!
//! Both implementations are **bit-identical**, extending the repo-wide
//! overflow-free-i32 contract to the epilogue stages:
//!
//! * **Requant** divides an i32 by a positive i32 with floor semantics.
//!   The AVX2 path computes `floor(f64(a) / f64(b))`.  For integers
//!   `a`, `b > 0` with `|a| < 2^53` this equals `a.div_euclid(b)`
//!   *exactly*: both operands are exactly representable, the correctly
//!   rounded quotient errs by at most `(|a|/b)·2⁻⁵³ < 1/b`, and the
//!   true quotient is at least `1/b` away from the nearest wrong
//!   integer boundary (or exactly on a boundary, where division is
//!   exact), so `floor` cannot cross it.  All inputs are i32, far
//!   inside `2^53`.
//! * **Clamp-by-pack**: `_mm_packs_epi32` + `_mm_packs_epi16`
//!   saturate i32 → i16 → i8, which composes to exactly
//!   `.clamp(-128, 127)` for any i32 — no separate clamp needed on the
//!   int8-output paths.  The i32-output residual path instead clamps
//!   on the ±128/127 rails with `_mm256_min/max_epi32` before adding
//!   the residual.
//! * **LayerNorm** rows are vectorized only when a per-row guard
//!   proves every f64 intermediate is an exactly-representable
//!   integer: with `spread = max − min` of the row, the guard requires
//!   `d ≤ 2^20`, `spread ≤ 2^21` and `spread²·d < 2^53` (the mean lies
//!   in `[min, max]`, so `|v − mean| ≤ spread` bounds every centered
//!   term; the squared-deviation sum then stays below `2^53` and f64
//!   accumulation is exact in any order).  The per-element chain is
//!   exact by the same floor-division argument (`|c·32| ≤ 2^26`,
//!   `|y·g| ≤ 2^33`, divisors `sd ≤ 2^21` and 64 exactly
//!   representable), and values are clamped in the f64 domain before
//!   `_mm256_cvtpd_epi32` (which would saturate out-of-range inputs to
//!   `i32::MIN`).  A row that fails the guard — impossible for real
//!   datapath magnitudes, reachable in adversarial tests — falls back
//!   to the scalar row, bit-exactly.
//!
//! ## The escape hatch
//!
//! `HCCS_FORCE_UNFUSED=1` (env, read once) or [`set_fused_override`] /
//! [`scoped_fused`] (in-process, tests) force the model layers back
//! onto the standalone-sweep path.  Because fused and unfused are
//! bit-exact, flipping this changes no result — it exists so the
//! differential tests, the CI matrix leg, and the benches can compare
//! the two dataflows on identical inputs.

use crate::simd::{self, SimdPath};
use std::sync::atomic::{AtomicU8, Ordering};

/// LayerNorm output target RMS: a normalized activation row has
/// (approximately) this integer standard deviation, which keeps every
/// downstream int8 MAC input well inside the rails.
pub const LN_TARGET: i64 = 32;

/// Fixed-point denominator of the LayerNorm gain: `gamma = 64` is the
/// identity gain, seeded gains live in [48, 80] (±25%).
pub const LN_GAMMA_DIV: i64 = 64;

/// Exact `floor(sqrt(n))` by Newton iteration (no fp round-trip, so
/// the result is platform-independent for the full u64 range).  The
/// seed `n/2 + 1` ≥ √n avoids the `n + 1` overflow at `u64::MAX`, and
/// the iterates stay below it, so nothing here can wrap.
pub fn isqrt_u64(n: u64) -> u64 {
    if n < 2 {
        return n;
    }
    let mut x = n / 2 + 1;
    let mut y = (x + n / x) / 2;
    while y < x {
        x = y;
        y = (x + n / x) / 2;
    }
    x
}

const FUSED_NONE: u8 = 0;
const FUSED_ON: u8 = 1;
const FUSED_OFF: u8 = 2;

static FUSED_OVERRIDE: AtomicU8 = AtomicU8::new(FUSED_NONE);

fn env_forces_unfused() -> bool {
    crate::runtime::env::force_unfused()
}

/// Whether the model layers should route projections through the fused
/// GEMM epilogue (the default) or the standalone per-layer sweeps.
/// Selection order mirrors [`crate::simd::active`]: in-process
/// override, then `HCCS_FORCE_UNFUSED` (read once), then fused.
pub fn fused_active() -> bool {
    match FUSED_OVERRIDE.load(Ordering::Relaxed) {
        FUSED_ON => true,
        FUSED_OFF => false,
        _ => !env_forces_unfused(),
    }
}

/// Process-wide fusion override (`None` restores env/default).  Both
/// dataflows are bit-exact, so flipping this mid-run changes no model
/// *result* — only which loop structure computes it.
pub fn set_fused_override(fused: Option<bool>) {
    let v = match fused {
        None => FUSED_NONE,
        Some(true) => FUSED_ON,
        Some(false) => FUSED_OFF,
    };
    FUSED_OVERRIDE.store(v, Ordering::Relaxed);
}

/// RAII form of [`set_fused_override`]: forces the dataflow until the
/// guard drops, then restores whatever override was in place before.
pub fn scoped_fused(fused: bool) -> FusedOverrideGuard {
    let prev = FUSED_OVERRIDE.load(Ordering::Relaxed);
    set_fused_override(Some(fused));
    FusedOverrideGuard { prev }
}

pub struct FusedOverrideGuard {
    prev: u8,
}

impl Drop for FusedOverrideGuard {
    fn drop(&mut self) {
        FUSED_OVERRIDE.store(self.prev, Ordering::Relaxed);
    }
}

/// What [`crate::linalg::PackedGemm::gemm_fused_into`] does to each
/// finished MC row block while it is cache-hot.  An enum rather than a
/// closure so the AVX2 block kernel stays monomorphic; every variant
/// reproduces the corresponding standalone-sweep sequence bit-exactly.
#[derive(Clone, Copy, Debug)]
pub enum Epilogue<'a> {
    /// `out = clamp(floor(acc / div))` — the plain [`requant`] sweep.
    Requant { div: i32 },
    /// Requant followed by ReLU (`max(0)`) — the FFN up-projection.
    RequantRelu { div: i32 },
    /// Requant, add the int8 residual stream, then integer LayerNorm —
    /// the attention-output and FFN-down projections.  `residual` is
    /// the full `rows × d_out` pre-projection activation tile; the
    /// epilogue indexes the rows belonging to the current block.
    RequantResidualLn {
        div: i32,
        residual: &'a [i8],
        gamma: &'a [i8],
        beta: &'a [i8],
    },
}

impl Epilogue<'_> {
    /// Validate operand shapes once per `gemm_fused_into` call (the
    /// per-block path stays assertion-free).
    pub(crate) fn check(&self, rows: usize, d_out: usize) {
        match *self {
            Epilogue::Requant { div } | Epilogue::RequantRelu { div } => {
                assert!(div > 0, "epilogue requant divisor must be positive");
            }
            Epilogue::RequantResidualLn {
                div,
                residual,
                gamma,
                beta,
            } => {
                assert!(div > 0, "epilogue requant divisor must be positive");
                assert_eq!(
                    residual.len(),
                    rows * d_out,
                    "epilogue residual is not a rows × d_out tile"
                );
                assert_eq!(gamma.len(), d_out, "epilogue gamma width mismatch");
                assert_eq!(beta.len(), d_out, "epilogue beta width mismatch");
            }
        }
    }

    /// Apply the epilogue to one finished row block.  `acc` holds the
    /// block's i32 accumulators (`block_rows × d_out`, starting at
    /// global row `row0`), `dst` the matching int8 output region.  The
    /// residual variant scribbles over `acc` (requant + residual in
    /// i32) before normalizing into `dst`.
    pub(crate) fn apply_block(
        &self,
        path: SimdPath,
        acc: &mut [i32],
        d_out: usize,
        row0: usize,
        dst: &mut [i8],
    ) {
        debug_assert_eq!(acc.len() % d_out, 0);
        debug_assert_eq!(dst.len(), acc.len());
        match *self {
            Epilogue::Requant { div } => requant_block(path, acc, div, false, dst),
            Epilogue::RequantRelu { div } => requant_block(path, acc, div, true, dst),
            Epilogue::RequantResidualLn {
                div,
                residual,
                gamma,
                beta,
            } => {
                let res = &residual[row0 * d_out..row0 * d_out + acc.len()];
                requant_add_residual_block(path, acc, res, div);
                layernorm_block(path, acc, d_out, gamma, beta, dst);
            }
        }
    }
}

/// Rescale i32 accumulators onto the int8 grid: floor division by a
/// positive divisor, clamped to the rails — identical semantics to the
/// QK^T logit rescale inside `hccs_attention` (scale_num = 1).
pub fn requant(accs: &[i32], div: i32, out: &mut Vec<i8>) {
    requant_with_path(simd::active(), accs, div, out);
}

/// [`requant`] with an explicitly pinned dispatch path.
pub fn requant_with_path(path: SimdPath, accs: &[i32], div: i32, out: &mut Vec<i8>) {
    let path = simd::require(path);
    super::gemm::resize_for_overwrite(out, accs.len());
    requant_block(path, accs, div, false, out);
}

/// Integer LayerNorm over each width-`d` row of `x32`: integer mean,
/// integer variance, Newton `isqrt`, then a fixed-point gain/bias.
/// Output rows have RMS ≈ [`LN_TARGET`] before the ±25% seeded gain.
pub fn layernorm_rows(x32: &[i32], d: usize, gamma: &[i8], beta: &[i8], out: &mut Vec<i8>) {
    layernorm_rows_with_path(simd::active(), x32, d, gamma, beta, out);
}

/// [`layernorm_rows`] with an explicitly pinned dispatch path.
pub fn layernorm_rows_with_path(
    path: SimdPath,
    x32: &[i32],
    d: usize,
    gamma: &[i8],
    beta: &[i8],
    out: &mut Vec<i8>,
) {
    let path = simd::require(path);
    super::gemm::resize_for_overwrite(out, x32.len());
    layernorm_block(path, x32, d, gamma, beta, out);
}

/// Requant one block into int8, optionally fusing the FFN ReLU.
pub(crate) fn requant_block(path: SimdPath, acc: &[i32], div: i32, relu: bool, dst: &mut [i8]) {
    debug_assert!(div > 0);
    debug_assert_eq!(dst.len(), acc.len());
    match path {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 only reaches here through simd::require (AVX2
        // available); dst.len() == acc.len() bounds every store.
        SimdPath::Avx2 => unsafe { avx2::requant(acc, div, relu, dst) },
        _ => {
            for (o, &v) in dst.iter_mut().zip(acc) {
                let y = v.div_euclid(div).clamp(-128, 127) as i8;
                *o = if relu { y.max(0) } else { y };
            }
        }
    }
}

/// In-place `acc[i] = residual[i] + clamp(floor(acc[i] / div))` — the
/// requant + residual-add half of the LayerNorm epilogue, kept in i32
/// because the sum feeds the normalization (it can reach ±256, outside
/// int8).
pub(crate) fn requant_add_residual_block(path: SimdPath, acc: &mut [i32], res: &[i8], div: i32) {
    debug_assert!(div > 0);
    debug_assert_eq!(res.len(), acc.len());
    match path {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 only reaches here through simd::require (AVX2
        // available); res.len() == acc.len() bounds the paired loads.
        SimdPath::Avx2 => unsafe { avx2::requant_add_residual(acc, res, div) },
        _ => {
            for (a, &r) in acc.iter_mut().zip(res) {
                *a = i32::from(r) + a.div_euclid(div).clamp(-128, 127);
            }
        }
    }
}

/// One LayerNorm output element — the scalar reference transform, also
/// the tail/fallback of the AVX2 row.
#[inline]
fn scalar_ln_elem(v: i32, mean: i64, sd: i64, g: i8, b: i8) -> i8 {
    let y = ((i64::from(v) - mean) * LN_TARGET).div_euclid(sd);
    let y = (y * i64::from(g)).div_euclid(LN_GAMMA_DIV) + i64::from(b);
    y.clamp(-128, 127) as i8
}

/// One full LayerNorm row, scalar (the original `norm.rs` loop).
fn scalar_ln_row(xr: &[i32], gamma: &[i8], beta: &[i8], or: &mut [i8]) {
    let d = xr.len() as i64;
    let sum: i64 = xr.iter().map(|&v| i64::from(v)).sum();
    let mean = sum.div_euclid(d);
    let var = xr
        .iter()
        .map(|&v| {
            let c = i64::from(v) - mean;
            c * c
        })
        .sum::<i64>()
        .div_euclid(d);
    let sd = (isqrt_u64(var as u64) as i64).max(1);
    for ((o, &v), (&g, &b)) in or.iter_mut().zip(xr).zip(gamma.iter().zip(beta)) {
        *o = scalar_ln_elem(v, mean, sd, g, b);
    }
}

/// Whether the AVX2 LayerNorm row is provably exact: `|v − mean| ≤
/// spread` for every element (the mean lies in `[min, max]`), so the
/// guard bounds every f64 intermediate below `2^53`.  The `spread ≤
/// 2^21` cap also keeps the guard product itself inside i64.
#[cfg(target_arch = "x86_64")]
fn ln_row_vectorizable(d: usize, spread: i64) -> bool {
    (d as i64) <= 1 << 20 && spread <= 1 << 21 && spread * spread * (d as i64) < 1 << 53
}

/// LayerNorm over the rows of one block, dispatching per `path`.  Row
/// stats (sum, rails) are scalar i64 either way; the AVX2 arm
/// vectorizes the variance accumulation and the element transform when
/// [`ln_row_vectorizable`] holds, and falls back to the scalar row —
/// bit-exactly — when it does not.
pub(crate) fn layernorm_block(
    path: SimdPath,
    x32: &[i32],
    d: usize,
    gamma: &[i8],
    beta: &[i8],
    out: &mut [i8],
) {
    debug_assert!(d > 0 && x32.len() % d == 0);
    debug_assert_eq!(out.len(), x32.len());
    debug_assert_eq!(gamma.len(), d);
    debug_assert_eq!(beta.len(), d);
    for (xr, or) in x32.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        match path {
            #[cfg(target_arch = "x86_64")]
            SimdPath::Avx2 => {
                let mut sum = 0i64;
                let (mut lo, mut hi) = (i32::MAX, i32::MIN);
                for &v in xr {
                    sum += i64::from(v);
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                let spread = i64::from(hi) - i64::from(lo);
                if ln_row_vectorizable(d, spread) {
                    let mean = sum.div_euclid(d as i64);
                    // SAFETY: path == Avx2 passed simd::require (AVX2
                    // available); the vectorizable guard bounds every
                    // f64 intermediate below 2^53, and gamma/beta/or
                    // share xr's checked row length.
                    unsafe {
                        let var = avx2::row_sumsq(xr, mean).div_euclid(d as i64);
                        let sd = (isqrt_u64(var as u64) as i64).max(1);
                        avx2::ln_row(xr, mean, sd, gamma, beta, or);
                    }
                } else {
                    scalar_ln_row(xr, gamma, beta, or);
                }
            }
            _ => scalar_ln_row(xr, gamma, beta, or),
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 epilogue kernels.  Exactness arguments are in the module
    //! docs; in short, every f64 operation here either produces an
    //! exactly-representable integer or is a floor-division whose
    //! single rounding provably cannot cross an integer boundary.
    use std::arch::x86_64::*;

    /// `floor(v / div)` for 8 i32 lanes via f64, returned as two 4-lane
    /// i32 halves (lanes 0–3, lanes 4–7).  Exact for every i32
    /// numerator and positive i32 divisor; the quotient magnitude never
    /// exceeds `|v|`, so `_mm256_cvtpd_epi32` (exact on integral
    /// in-range inputs) cannot saturate.
    ///
    /// SAFETY: requires AVX2 only — register math, no memory.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn floor_div8(v: __m256i, div: __m256d) -> (__m128i, __m128i) {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        let qlo = _mm256_cvtpd_epi32(_mm256_floor_pd(_mm256_div_pd(_mm256_cvtepi32_pd(lo), div)));
        let qhi = _mm256_cvtpd_epi32(_mm256_floor_pd(_mm256_div_pd(_mm256_cvtepi32_pd(hi), div)));
        (qlo, qhi)
    }

    /// Vectorized [`super::requant_block`]: floor-div, then the
    /// i32→i16→i8 saturating packs (≡ `.clamp(-128, 127)`), then an
    /// optional ReLU on the packed bytes.
    ///
    /// SAFETY: requires AVX2; `dst.len() == acc.len()` (the dispatcher
    /// asserts it) bounds every load/store pair.
    #[target_feature(enable = "avx2")]
    pub unsafe fn requant(acc: &[i32], div: i32, relu: bool, dst: &mut [i8]) {
        let divv = _mm256_set1_pd(f64::from(div));
        let zero = _mm_setzero_si128();
        let mut i = 0;
        while i + 8 <= acc.len() {
            // SAFETY: i + 8 <= acc.len() bounds the 32-byte load, and
            // dst (same length) has >= 8 writable bytes at i for the
            // 8-byte store.
            unsafe {
                let v = _mm256_loadu_si256(acc.as_ptr().add(i).cast());
                let (qlo, qhi) = floor_div8(v, divv);
                let w16 = _mm_packs_epi32(qlo, qhi);
                let mut w8 = _mm_packs_epi16(w16, w16);
                if relu {
                    w8 = _mm_max_epi8(w8, zero);
                }
                _mm_storel_epi64(dst.as_mut_ptr().add(i).cast(), w8);
            }
            i += 8;
        }
        for j in i..acc.len() {
            let y = acc[j].div_euclid(div).clamp(-128, 127) as i8;
            dst[j] = if relu { y.max(0) } else { y };
        }
    }

    /// Vectorized [`super::requant_add_residual_block`]: floor-div,
    /// clamp on the i32 rails (the output stays i32, so the pack trick
    /// does not apply), add the sign-extended int8 residual, store
    /// back over `acc`.
    ///
    /// SAFETY: requires AVX2; `res.len() == acc.len()` (the dispatcher
    /// asserts it) bounds every load/store pair.
    #[target_feature(enable = "avx2")]
    pub unsafe fn requant_add_residual(acc: &mut [i32], res: &[i8], div: i32) {
        let divv = _mm256_set1_pd(f64::from(div));
        let lo_rail = _mm256_set1_epi32(-128);
        let hi_rail = _mm256_set1_epi32(127);
        let mut i = 0;
        while i + 8 <= acc.len() {
            // SAFETY: i + 8 <= acc.len() bounds the 32-byte acc
            // load/store and the 8-byte residual load (equal lengths).
            unsafe {
                let v = _mm256_loadu_si256(acc.as_ptr().add(i).cast());
                let (qlo, qhi) = floor_div8(v, divv);
                let q = _mm256_set_m128i(qhi, qlo);
                let q = _mm256_min_epi32(_mm256_max_epi32(q, lo_rail), hi_rail);
                let r = _mm256_cvtepi8_epi32(_mm_loadl_epi64(res.as_ptr().add(i).cast()));
                let s = _mm256_add_epi32(q, r);
                _mm256_storeu_si256(acc.as_mut_ptr().add(i).cast(), s);
            }
            i += 8;
        }
        for j in i..acc.len() {
            acc[j] = i32::from(res[j]) + acc[j].div_euclid(div).clamp(-128, 127);
        }
    }

    /// Exact f64 accumulation of `Σ (v − mean)²` over one row.  The
    /// caller's [`super::ln_row_vectorizable`] guard bounds every
    /// partial sum below `2^53`, so each f64 add is exact and the
    /// accumulation order (4 lanes + tail) does not matter.
    ///
    /// SAFETY: requires AVX2; reads stay inside `xr`'s bounds.
    #[target_feature(enable = "avx2")]
    pub unsafe fn row_sumsq(xr: &[i32], mean: i64) -> i64 {
        let meanv = _mm256_set1_pd(mean as f64);
        let mut accv = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= xr.len() {
            // SAFETY: i + 4 <= xr.len() bounds the 16-byte load.
            unsafe {
                let v = _mm256_cvtepi32_pd(_mm_loadu_si128(xr.as_ptr().add(i).cast()));
                let c = _mm256_sub_pd(v, meanv);
                accv = _mm256_add_pd(accv, _mm256_mul_pd(c, c));
            }
            i += 4;
        }
        let mut lanes = [0.0f64; 4];
        // SAFETY: lanes is exactly 4 f64 — 32 writable bytes.
        unsafe { _mm256_storeu_pd(lanes.as_mut_ptr(), accv) };
        let mut total = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        for &v in &xr[i..] {
            let c = (i64::from(v) - mean) as f64;
            total += c * c;
        }
        total as i64
    }

    /// Row-invariant constants of the LayerNorm element transform.
    struct LnConsts {
        mean: __m256d,
        sd: __m256d,
        tgt: __m256d,
        gdiv: __m256d,
        lo: __m256d,
        hi: __m256d,
    }

    /// Four output elements: `floor(((v − mean)·32) / sd)` →
    /// `floor((y·g) / 64) + b` → clamp in f64 (before the convert,
    /// which saturates out-of-range inputs to `i32::MIN`) → i32.
    ///
    /// SAFETY: requires AVX2 only — register math, no memory.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn ln_lane(v: __m256d, g: __m256d, b: __m256d, k: &LnConsts) -> __m128i {
        let y = _mm256_floor_pd(_mm256_div_pd(
            _mm256_mul_pd(_mm256_sub_pd(v, k.mean), k.tgt),
            k.sd,
        ));
        let y = _mm256_add_pd(_mm256_floor_pd(_mm256_div_pd(_mm256_mul_pd(y, g), k.gdiv)), b);
        let y = _mm256_min_pd(_mm256_max_pd(y, k.lo), k.hi);
        _mm256_cvtpd_epi32(y)
    }

    /// Vectorized LayerNorm element transform over one row whose stats
    /// (`mean`, `sd`) the caller already computed.  Only called under
    /// the exactness guard.
    ///
    /// SAFETY: requires AVX2; `gamma`/`beta`/`or` share `xr`'s length
    /// (the dispatcher asserts it), bounding every load/store.
    #[target_feature(enable = "avx2")]
    pub unsafe fn ln_row(xr: &[i32], mean: i64, sd: i64, gamma: &[i8], beta: &[i8], or: &mut [i8]) {
        let k = LnConsts {
            mean: _mm256_set1_pd(mean as f64),
            sd: _mm256_set1_pd(sd as f64),
            tgt: _mm256_set1_pd(super::LN_TARGET as f64),
            gdiv: _mm256_set1_pd(super::LN_GAMMA_DIV as f64),
            lo: _mm256_set1_pd(-128.0),
            hi: _mm256_set1_pd(127.0),
        };
        let mut i = 0;
        while i + 8 <= xr.len() {
            // SAFETY: i + 8 <= xr.len() bounds the 32-byte x load, the
            // 8-byte gamma/beta loads, and the 8-byte output store —
            // all four slices share xr's length.
            unsafe {
                let v = _mm256_loadu_si256(xr.as_ptr().add(i).cast());
                let vlo = _mm256_cvtepi32_pd(_mm256_castsi256_si128(v));
                let vhi = _mm256_cvtepi32_pd(_mm256_extracti128_si256::<1>(v));
                let g32 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(gamma.as_ptr().add(i).cast()));
                let glo = _mm256_cvtepi32_pd(_mm256_castsi256_si128(g32));
                let ghi = _mm256_cvtepi32_pd(_mm256_extracti128_si256::<1>(g32));
                let b32 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(beta.as_ptr().add(i).cast()));
                let blo = _mm256_cvtepi32_pd(_mm256_castsi256_si128(b32));
                let bhi = _mm256_cvtepi32_pd(_mm256_extracti128_si256::<1>(b32));
                let qlo = ln_lane(vlo, glo, blo, &k);
                let qhi = ln_lane(vhi, ghi, bhi, &k);
                // Values are clamped to [-128, 127] already, so the
                // saturating packs are lossless order-preserving narrows.
                let w16 = _mm_packs_epi32(qlo, qhi);
                let w8 = _mm_packs_epi16(w16, w16);
                _mm_storel_epi64(or.as_mut_ptr().add(i).cast(), w8);
            }
            i += 8;
        }
        for j in i..xr.len() {
            or[j] = super::scalar_ln_elem(xr[j], mean, sd, gamma[j], beta[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn assert_both_paths<F: FnMut(SimdPath) -> Vec<i8>>(label: &str, mut f: F) {
        if !simd::avx2_available() {
            return;
        }
        let scalar = f(SimdPath::Scalar);
        let avx2 = f(SimdPath::Avx2);
        assert_eq!(scalar, avx2, "{label}: AVX2 diverged from scalar");
    }

    #[test]
    fn isqrt_is_exact_floor() {
        for n in 0u64..100_000 {
            let r = isqrt_u64(n);
            assert!(r * r <= n, "n={n}");
            assert!((r + 1) * (r + 1) > n, "n={n}");
        }
        for n in [u64::MAX, u64::MAX - 1, 1 << 62, (1 << 32) - 1, 1 << 32] {
            let r = isqrt_u64(n);
            assert!(r.checked_mul(r).is_some_and(|s| s <= n));
            assert!((r + 1).checked_mul(r + 1).is_none_or(|s| s > n));
        }
    }

    #[test]
    fn requant_uses_floor_division_and_clamps() {
        let mut out = Vec::new();
        requant(&[-5, 5, 10_000, -10_000, 16], 16, &mut out);
        assert_eq!(out, vec![-1, 0, 127, -128, 1]);
    }

    #[test]
    fn requant_paths_agree_on_adversarial_inputs() {
        let mut rng = Xoshiro256::new(41);
        for len in [0usize, 1, 7, 8, 9, 31, 64, 257] {
            for div in [1i32, 2, 3, 7, 127, 4096, i32::MAX] {
                let mut accs: Vec<i32> = (0..len).map(|_| rng.next_u64() as i32).collect();
                // Seed the rails explicitly: i32::MIN / 1 is the worst
                // case for the f64 floor-division and the i32 clamp.
                for v in [i32::MIN, i32::MAX, 0, -1, 1] {
                    if !accs.is_empty() {
                        let at = rng.below(accs.len() as u64) as usize;
                        accs[at] = v;
                    }
                }
                for relu in [false, true] {
                    assert_both_paths(&format!("requant len={len} div={div} relu={relu}"), |p| {
                        let mut dst = vec![0i8; accs.len()];
                        requant_block(p, &accs, div, relu, &mut dst);
                        dst
                    });
                }
            }
        }
    }

    #[test]
    fn residual_requant_paths_agree_and_match_composition() {
        let mut rng = Xoshiro256::new(43);
        for len in [0usize, 3, 8, 40, 129] {
            for div in [1i32, 5, 1000, i32::MAX] {
                let accs: Vec<i32> = (0..len).map(|_| rng.next_u64() as i32).collect();
                let res: Vec<i8> = (0..len).map(|_| rng.i8()).collect();
                // Reference: the unfused sweep order (requant to int8,
                // then widen-and-add).
                let mut q = Vec::new();
                requant_with_path(SimdPath::Scalar, &accs, div, &mut q);
                let want: Vec<i32> = q
                    .iter()
                    .zip(&res)
                    .map(|(&v, &r)| i32::from(r) + i32::from(v))
                    .collect();
                for path in [SimdPath::Scalar, SimdPath::Avx2] {
                    if path == SimdPath::Avx2 && !simd::avx2_available() {
                        continue;
                    }
                    let mut acc = accs.clone();
                    requant_add_residual_block(path, &mut acc, &res, div);
                    assert_eq!(acc, want, "residual path={path:?} len={len} div={div}");
                }
            }
        }
    }

    #[test]
    fn layernorm_standardizes_rows() {
        // A high-variance row and a shifted copy must normalize to the
        // same output (shift invariance of (x - mean) / sd).
        let row: Vec<i32> = (0..64).map(|i| i * 50 - 1600).collect();
        let shifted: Vec<i32> = row.iter().map(|v| v + 700).collect();
        let gamma = vec![64i8; 64];
        let beta = vec![0i8; 64];
        let (mut a, mut b) = (Vec::new(), Vec::new());
        layernorm_rows(&row, 64, &gamma, &beta, &mut a);
        layernorm_rows(&shifted, 64, &gamma, &beta, &mut b);
        assert_eq!(a, b);
        // RMS lands near LN_TARGET.
        let rms = (a.iter().map(|&v| f64::from(v) * f64::from(v)).sum::<f64>() / 64.0).sqrt();
        assert!((20.0..=44.0).contains(&rms), "rms {rms}");
    }

    #[test]
    fn layernorm_constant_row_is_beta() {
        let gamma = vec![64i8; 4];
        let beta = vec![7i8; 4];
        let mut out = Vec::new();
        layernorm_rows(&[5, 5, 5, 5], 4, &gamma, &beta, &mut out);
        assert_eq!(out, vec![7, 7, 7, 7]);
    }

    #[test]
    fn layernorm_paths_agree_including_guard_fallback() {
        let mut rng = Xoshiro256::new(47);
        // (d, rows, magnitude): the 2_000_000 magnitude rows exceed the
        // spread ≤ 2^21 guard, forcing the AVX2 arm onto the bit-exact
        // scalar fallback; the small rows take the vector route.
        for &(d, rows, mag) in &[
            (1usize, 5usize, 400i64),
            (4, 3, 400),
            (8, 2, 400),
            (12, 4, 127),
            (32, 4, 30_000),
            (64, 2, 2_000_000),
            (96, 1, 1),
        ] {
            let x32: Vec<i32> =
                (0..d * rows).map(|_| rng.range_i64(-mag, mag) as i32).collect();
            let gamma: Vec<i8> = (0..d).map(|_| rng.range_i64(48, 80) as i8).collect();
            let beta: Vec<i8> = (0..d).map(|_| rng.i8()).collect();
            assert_both_paths(&format!("layernorm d={d} rows={rows} mag={mag}"), |p| {
                let mut out = Vec::new();
                layernorm_rows_with_path(p, &x32, d, &gamma, &beta, &mut out);
                out
            });
        }
    }

    #[test]
    fn epilogue_matches_standalone_sweeps() {
        let mut rng = Xoshiro256::new(53);
        let (rows, d) = (13usize, 24usize);
        let accs: Vec<i32> =
            (0..rows * d).map(|_| rng.range_i64(-100_000, 100_000) as i32).collect();
        let residual: Vec<i8> = (0..rows * d).map(|_| rng.i8()).collect();
        let gamma: Vec<i8> = (0..d).map(|_| rng.range_i64(48, 80) as i8).collect();
        let beta: Vec<i8> = (0..d).map(|_| rng.i8()).collect();
        let div = 713;

        // Unfused reference: requant → widen+residual → layernorm.
        let mut q = Vec::new();
        requant_with_path(SimdPath::Scalar, &accs, div, &mut q);
        let x32: Vec<i32> = q
            .iter()
            .zip(&residual)
            .map(|(&v, &r)| i32::from(r) + i32::from(v))
            .collect();
        let mut want = Vec::new();
        layernorm_rows_with_path(SimdPath::Scalar, &x32, d, &gamma, &beta, &mut want);

        let ep = Epilogue::RequantResidualLn {
            div,
            residual: &residual,
            gamma: &gamma,
            beta: &beta,
        };
        ep.check(rows, d);
        // Apply block-at-a-time with a ragged split, as the fused GEMM
        // loop does, on both paths.
        for path in [SimdPath::Scalar, SimdPath::Avx2] {
            if path == SimdPath::Avx2 && !simd::avx2_available() {
                continue;
            }
            let mut dst = vec![0i8; rows * d];
            for (blk, row0) in [(0usize..5usize, 0usize), (5..13, 5)] {
                let mut acc = accs[blk.start * d..blk.end * d].to_vec();
                ep.apply_block(path, &mut acc, d, row0, &mut dst[blk.start * d..blk.end * d]);
            }
            assert_eq!(dst, want, "fused epilogue diverged on {path:?}");
        }
    }

    #[test]
    fn fused_override_wins_and_restores() {
        {
            let _g = scoped_fused(false);
            assert!(!fused_active());
            {
                let _inner = scoped_fused(true);
                assert!(fused_active());
            }
            assert!(!fused_active());
        }
        // Back to env/default — under the test env (no
        // HCCS_FORCE_UNFUSED) that is fused, but another concurrent
        // test may hold an override, so only check it is a valid state.
        let _ = fused_active();
    }
}
