//! Integer linear algebra core: the one place every MAC loop in the
//! stack lives.
//!
//! Before this module existed the encoder computed each projection,
//! FFN, QK^T, and p̂·V through its own scalar dot loop (`norm.rs` had a
//! `matmul_i8`, `encoder.rs` a private `dot_i8`, `attention.rs` two
//! inline MAC loops).  Everything now routes through three kernels with
//! a shared contract — every output cell is an i32 sum of bounded int8
//! products that **cannot overflow** under the repo's shape limits, so
//! i32 addition is exactly associative/commutative and *any*
//! accumulation order (scalar ascending-k, lane blocking, AVX2 madd
//! pairs) is bit-exact with the scalar reference.  Each kernel ships a
//! scalar and an explicit-AVX2 implementation behind
//! [`crate::simd::active`] runtime dispatch (`HCCS_FORCE_SCALAR=1`
//! forces the fallback; `*_with_path` variants pin a path for the
//! differential harness):
//!
//! * [`PackedGemm`] — weights-stationary int8×int8→i32 GEMM.  The
//!   weight matrix is transposed and packed **once** (at
//!   [`crate::model::NativeModel`] construction) into column panels of
//!   [`gemm::NR`] output units interleaved along k; the kernel then
//!   walks activation rows in blocks of [`gemm::MC`] so a panel stays
//!   L1-resident while a row block streams through it.  This is the
//!   paper-§IV MAC-array mapping on the CPU: the inner loop is a
//!   broadcast-multiply-accumulate over `NR` independent i32 lanes
//!   (scalar path) or an `_mm256_madd_epi16` two-k fusion over one
//!   AVX2 register (SIMD path).  One `gemm_into` pass additionally
//!   spans the [`crate::runtime::pool`] worker pool, one [`gemm::MC`]
//!   row block per work item — disjoint output regions make the result
//!   independent of thread count and claim order.
//! * [`gemm_nt_into`] — A·Bᵀ for two row-major int8 operands (both
//!   sides are *activations*: Q against K).  No packing — K changes
//!   every call — but the kernel register-blocks four B rows per pass
//!   so each A row is loaded once per four outputs.
//!   [`gemm_nt_bounded_into`] is the column-bounded form: only the
//!   first `n_active` output columns (the valid keys of a masked
//!   attention row) are computed, the pad columns are zeroed — no MAC
//!   is ever issued against a pad key.
//! * [`gemm_pv_into`] — the i32×int8 probability mix p̂·V, with the
//!   p̂ = 0 sparsity shortcut the clamped HCCS tails make profitable.
//!   [`gemm_pv_bounded_into`] bounds the mix to the first `c_active`
//!   keys so masked pad columns are skipped structurally.
//!
//! [`matmul_i8_ref`] is the scalar reference oracle (the old
//! `norm.rs::matmul_i8` loop, verbatim): slow, obviously correct, and
//! property-tested against [`PackedGemm`] over ragged shapes in
//! `tests/proptests.rs`.  [`dot_i8`] is the canonical int8 dot product
//! every other helper folds down to.
//!
//! The [`epilogue`] module closes the memory-traffic gap the GEMM
//! consolidation left open: [`PackedGemm::gemm_fused_into`] applies a
//! caller-selected [`Epilogue`] (requant → optional residual add →
//! optional integer LayerNorm) to each finished `MC`-row block while
//! it is still cache-resident, so the i32 accumulator tile never
//! round-trips through memory.  The standalone [`requant`] /
//! [`layernorm_rows`] sweeps (for the call sites that stay unfused)
//! live there too, vectorized behind the same dispatch;
//! `HCCS_FORCE_UNFUSED=1` / [`scoped_fused`] flip the model layers
//! back onto the standalone-sweep dataflow, which stays bit-exact.
//!
//! See `docs/ARCHITECTURE.md` §"Layer: linalg" for the packing diagram
//! and the batch-axis dataflow, §"Layer: fused epilogues" for the
//! fused loop order and exactness bounds, and `benches/gemm.rs` for
//! the measured packed-vs-scalar and fused-vs-unfused wins
//! (`BENCH_gemm.json`).

pub mod epilogue;
pub mod gemm;

pub use epilogue::{
    fused_active, layernorm_rows, layernorm_rows_with_path, requant, requant_with_path,
    scoped_fused, set_fused_override, Epilogue, FusedOverrideGuard,
};
pub use gemm::{
    dot_i8, gemm_nt_bounded_into, gemm_nt_bounded_into_with_path, gemm_nt_into,
    gemm_pv_bounded_into, gemm_pv_bounded_into_with_path, gemm_pv_into, matmul_i8_ref,
    resize_for_overwrite, PackedGemm, ScratchCell,
};
