//! Hand-rolled flag parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments.  Unknown flags are an error so typos fail fast.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid(String, String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown flag --{name}"),
            CliError::MissingValue(name) => write!(f, "flag --{name} requires a value"),
            CliError::Invalid(name, value, why) => {
                write!(f, "invalid value {value:?} for --{name}: {why}")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv` (without the program name). `known` lists accepted
    /// flag names; names ending in `=` take a value, bare names are
    /// booleans.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known: &[&str],
    ) -> Result<Args, CliError> {
        let value_flags: Vec<&str> = known
            .iter()
            .filter(|k| k.ends_with('='))
            .map(|k| k.trim_end_matches('='))
            .collect();
        let bool_flags: Vec<&str> = known.iter().filter(|k| !k.ends_with('=')).copied().collect();

        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if value_flags.contains(&name.as_str()) {
                    let v = match inline {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    out.flags.insert(name, v);
                } else if bool_flags.contains(&name.as_str()) {
                    out.flags.insert(name, inline.unwrap_or_else(|| "true".into()));
                } else {
                    return Err(CliError::Unknown(name));
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env(known: &[&str]) -> Result<Args, CliError> {
        Self::parse(std::env::args().skip(1), known)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn parse_num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: T::Err| {
                CliError::Invalid(name.to_string(), v.to_string(), e.to_string())
            }),
        }
    }

    /// Like [`Self::parse_num`], but rejects values below `min` (e.g.
    /// `--shards 0` must fail fast rather than start a dead engine).
    pub fn parse_num_at_least<T>(&self, name: &str, default: T, min: T) -> Result<T, CliError>
    where
        T: std::str::FromStr + PartialOrd + std::fmt::Display + Copy,
        T::Err: std::fmt::Display,
    {
        let v = self.parse_num(name, default)?;
        if v < min {
            return Err(CliError::Invalid(
                name.to_string(),
                v.to_string(),
                format!("must be >= {min}"),
            ));
        }
        Ok(v)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_value_and_bool_flags() {
        let a =
            Args::parse(argv("sub --n 64 --fast --mode=i8_clb"), &["n=", "mode=", "fast"]).unwrap();
        assert_eq!(a.positional(), &["sub".to_string()]);
        assert_eq!(a.get("n"), Some("64"));
        assert_eq!(a.get("mode"), Some("i8_clb"));
        assert!(a.flag("fast"));
        assert_eq!(a.parse_num("n", 0usize).unwrap(), 64);
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(matches!(
            Args::parse(argv("--nope"), &["n="]),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(matches!(
            Args::parse(argv("--n"), &["n="]),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(argv("--n abc"), &["n="]).unwrap();
        assert!(a.parse_num("n", 0usize).is_err());
    }

    #[test]
    fn lower_bound_is_enforced() {
        let a = Args::parse(argv("--shards 0"), &["shards="]).unwrap();
        assert!(a.parse_num_at_least("shards", 1usize, 1).is_err());
        let a = Args::parse(argv("--shards 4"), &["shards="]).unwrap();
        assert_eq!(a.parse_num_at_least("shards", 1usize, 1).unwrap(), 4);
        // Default is used (and checked) when the flag is absent.
        let a = Args::parse(argv(""), &["shards="]).unwrap();
        assert_eq!(a.parse_num_at_least("shards", 2usize, 1).unwrap(), 2);
    }
}
