//! Lightweight metrics: counters, gauges, and latency histograms with
//! percentile queries.  Shared by the server, the coordinator, and the
//! benchmark harnesses.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Monotonic event counter (lock-free).
#[derive(Default, Debug)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Up/down level gauge (lock-free) — current value, not event count.
/// Used for live state like open connections.
#[derive(Default, Debug)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn add(&self, n: i64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn set(&self, n: i64) {
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log-scale value histogram (~4% relative bucket width,
/// covers 1 .. ~2³²).  Latencies are recorded in microseconds via
/// [`Histogram::record`]; unit-less values (e.g. observed batch sizes)
/// go through [`Histogram::record_value`].  By convention the metric
/// *name* carries the unit (`coordinator.queue_us`, `native.batch_rows`).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const BUCKETS_PER_OCTAVE: usize = 16;
const N_BUCKETS: usize = 32 * BUCKETS_PER_OCTAVE;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        if us <= 1 {
            return 0;
        }
        let log2 = (63 - us.leading_zeros() as u64) as usize;
        // The 4 fractional bits directly below the leading bit.  For
        // small octaves (log2 < 4) the value has fewer than 4 bits
        // below the leading one, so they must be shifted *up* into
        // place — the old `us >> saturating_sub` extracted the wrong
        // bits there and skewed every value in [2, 32) into the upper
        // buckets of its octave.
        let frac = if log2 >= 4 {
            (us >> (log2 - 4)) & 0xF
        } else {
            (us << (4 - log2)) & 0xF
        };
        (log2 * BUCKETS_PER_OCTAVE + frac as usize).min(N_BUCKETS - 1)
    }

    /// Lower edge (µs) represented by bucket `i` — the exact inverse of
    /// [`Self::bucket_of`]'s truncation: `bucket_value(bucket_of(v))`
    /// is `v` with everything below its top 5 bits dropped, so it never
    /// exceeds `v` and sits within one bucket width
    /// (`max(1, 2^(⌊log2 v⌋-4))`) of it.  Values below 32 round-trip
    /// exactly.
    fn bucket_value(i: usize) -> u64 {
        let log2 = i / BUCKETS_PER_OCTAVE;
        let frac = (i % BUCKETS_PER_OCTAVE) as u64;
        if log2 >= 4 {
            (16 + frac) << (log2 - 4)
        } else {
            (16 + frac) >> (4 - log2)
        }
    }

    pub fn record(&self, d: Duration) {
        self.record_value(d.as_micros() as u64);
    }

    /// Record a raw value (the unit is whatever the metric name says).
    pub fn record_value(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(v, Ordering::Relaxed);
        self.max_us.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate percentile in microseconds (p in [0, 100]).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_value(i);
            }
        }
        self.max_us()
    }
}

/// Named registry so binaries can dump everything at exit.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Histogram::new()))
            .clone()
    }

    /// Sum of every counter whose name starts with `prefix` — the
    /// aggregate view over a per-shard family (the sharded engines
    /// register `<name>` plus `<name>.shard<K>` for each shard, so
    /// `sum_counters("scorer.requests.shard")` must equal the
    /// `scorer.requests` aggregate).
    pub fn sum_counters(&self, prefix: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .filter(|(name, _)| name.starts_with(prefix))
            .map(|(_, c)| c.get())
            .sum()
    }

    /// Human-readable dump, sorted by name.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name} = {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} = {}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            // Unit lives in the metric name by convention (`_us` for
            // latencies), so values print bare.
            out.push_str(&format!(
                "{name}: n={} mean={:.1} p50={} p95={} p99={} max={}\n",
                h.count(),
                h.mean_us(),
                h.percentile_us(50.0),
                h.percentile_us(95.0),
                h.percentile_us(99.0),
                h.max_us()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_adds() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let p50 = h.percentile_us(50.0);
        let p95 = h.percentile_us(95.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // ~4% bucket resolution.
        assert!((450..=560).contains(&p50), "p50={p50}");
        assert!((880..=1060).contains(&p95), "p95={p95}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn bucket_round_trip_is_within_one_bucket_width_up_to_1e9() {
        // Regression for the small-value fractional-bit extraction:
        // values in [2, 32) used to land in skewed buckets, so
        // percentile_us misreported sub-32µs latencies.  The round-trip
        // contract: bucket_value(bucket_of(v)) never exceeds v and sits
        // within one bucket width (max(1, 2^(log2 v)/16)) below it;
        // below 32 it is exact.
        fn check(v: u64) {
            let bv = Histogram::bucket_value(Histogram::bucket_of(v));
            let vc = v.max(1); // 0 and 1 share the first bucket
            let log2 = 63 - vc.leading_zeros() as u64;
            let width = ((1u64 << log2) >> 4).max(1);
            assert!(bv <= vc, "bucket_value {bv} above v={v}");
            assert!(vc - bv < width, "v={v}: edge {bv} further than width {width}");
            if (1..32).contains(&vc) {
                assert_eq!(bv, vc, "sub-32 values must round-trip exactly");
            }
        }
        for v in 0..=65536u64 {
            check(v);
        }
        let mut v = 65536u64;
        while v <= 1_000_000_000 {
            check(v - 1);
            check(v);
            check(v + 1);
            v = v * 3 / 2;
        }
    }

    #[test]
    fn sub_32us_percentiles_are_faithful() {
        // 1..=20µs uniformly: the median must come back as ~10µs, not
        // skewed into the octave tops as the old extraction did.
        let h = Histogram::new();
        for v in 1..=20u64 {
            h.record_value(v);
        }
        assert_eq!(h.percentile_us(50.0), 10);
        assert_eq!(h.percentile_us(100.0), 20);
        assert_eq!(h.percentile_us(5.0), 1);
    }

    #[test]
    fn histogram_handles_extremes() {
        let h = Histogram::new();
        h.record(Duration::from_micros(0));
        h.record(Duration::from_secs(3600));
        assert_eq!(h.count(), 2);
        assert!(h.percentile_us(100.0) >= 1_000_000);
    }

    #[test]
    fn record_value_feeds_the_same_buckets_as_durations() {
        let h = Histogram::new();
        h.record_value(8);
        h.record(Duration::from_micros(8));
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile_us(100.0), 8);
        assert_eq!(h.max_us(), 8);
        assert!((h.mean_us() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn registry_is_shared() {
        let r = Registry::default();
        r.counter("x").inc();
        r.counter("x").inc();
        assert_eq!(r.counter("x").get(), 2);
        assert!(r.render().contains("x = 2"));
    }

    #[test]
    fn gauge_goes_up_and_down() {
        let r = Registry::default();
        let g = r.gauge("net.active");
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        assert_eq!(r.gauge("net.active").get(), 1, "registry shares gauges");
        g.set(-3);
        assert_eq!(g.get(), -3);
        assert!(r.render().contains("net.active = -3"));
    }

    #[test]
    fn sum_counters_rolls_up_a_shard_family() {
        let r = Registry::default();
        r.counter("eng.requests").add(7);
        r.counter("eng.requests.shard0").add(3);
        r.counter("eng.requests.shard1").add(4);
        r.counter("eng.batches.shard0").add(99); // different family
        assert_eq!(r.sum_counters("eng.requests.shard"), 7);
        assert_eq!(r.sum_counters("eng.requests"), 14, "prefix includes the aggregate");
        assert_eq!(r.sum_counters("nope"), 0);
    }
}
