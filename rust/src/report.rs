//! Table and figure rendering shared by the experiment harnesses:
//! aligned-markdown tables, TSV emission, and ASCII line plots for the
//! figure reproductions.

/// A simple column-aligned table.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "ragged table row");
        self.rows.push(cells.to_vec());
    }

    /// Render as a markdown-style aligned table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out
    }

    /// Tab-separated emission (for plotting tools).
    pub fn to_tsv(&self) -> String {
        let mut out = self.header.join("\t");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join("\t"));
            out.push('\n');
        }
        out
    }
}

/// ASCII line plot for figure reproductions (log-ish friendly).
pub struct AsciiPlot {
    pub title: String,
    pub width: usize,
    pub height: usize,
    series: Vec<(String, Vec<(f64, f64)>)>,
}

impl AsciiPlot {
    pub fn new(title: &str) -> Self {
        Self { title: title.to_string(), width: 72, height: 20, series: Vec::new() }
    }

    pub fn series(&mut self, name: &str, points: Vec<(f64, f64)>) -> &mut Self {
        self.series.push((name.to_string(), points));
        self
    }

    pub fn render(&self) -> String {
        const MARKS: &[char] = &['*', 'o', '+', 'x', '#'];
        let all: Vec<(f64, f64)> =
            self.series.iter().flat_map(|(_, p)| p.iter().copied()).collect();
        if all.is_empty() {
            return format!("{}\n(no data)\n", self.title);
        }
        let (xmin, xmax) = min_max(all.iter().map(|p| p.0));
        let (ymin, ymax) = min_max(all.iter().map(|p| p.1));
        let xspan = (xmax - xmin).max(1e-12);
        let yspan = (ymax - ymin).max(1e-12);
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (si, (_, pts)) in self.series.iter().enumerate() {
            let mark = MARKS[si % MARKS.len()];
            for &(x, y) in pts {
                let cx = (((x - xmin) / xspan) * (self.width - 1) as f64).round() as usize;
                let cy = (((y - ymin) / yspan) * (self.height - 1) as f64).round() as usize;
                grid[self.height - 1 - cy][cx] = mark;
            }
        }
        let mut out = format!("{}\n", self.title);
        out.push_str(&format!("  y: {ymin:.3e} .. {ymax:.3e}\n"));
        for row in grid {
            out.push_str("  |");
            out.extend(row);
            out.push('\n');
        }
        out.push_str(&format!("  +{}\n", "-".repeat(self.width)));
        out.push_str(&format!("   x: {xmin:.1} .. {xmax:.1}\n"));
        for (si, (name, _)) in self.series.iter().enumerate() {
            out.push_str(&format!("   {} {}\n", MARKS[si % MARKS.len()], name));
        }
        out
    }
}

fn min_max(it: impl Iterator<Item = f64>) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for v in it {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (lo, hi)
}

/// Format a throughput in elements/s the way the paper does ("2.19G/s").
pub fn fmt_gps(eps: f64) -> String {
    format!("{:.2}G/s", eps / 1e9)
}

/// Format a speedup ("15.1x").
pub fn fmt_speedup(x: f64) -> String {
    if x >= 10.0 {
        format!("{x:.1}x")
    } else {
        format!("{x:.2}x")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "long-header"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("## T"));
        assert!(s.lines().filter(|l| l.starts_with('|')).count() == 4);
        let widths: Vec<usize> =
            s.lines().filter(|l| l.starts_with('|')).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "ragged render");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn plot_renders_all_series() {
        let mut p = AsciiPlot::new("fig");
        p.series("lin", (0..10).map(|i| (i as f64, i as f64)).collect());
        p.series("quad", (0..10).map(|i| (i as f64, (i * i) as f64)).collect());
        let s = p.render();
        assert!(s.contains('*') && s.contains('o'));
        assert!(s.contains("lin") && s.contains("quad"));
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_gps(2.19e9), "2.19G/s");
        assert_eq!(fmt_speedup(15.1), "15.1x");
        assert_eq!(fmt_speedup(4.6), "4.60x");
    }
}
