//! Stub of the `xla` (PJRT) crate API surface used by [`crate::runtime`].
//!
//! The real backend is the `xla` Rust bindings over PJRT; that crate (and
//! the XLA shared library it links) is not available in this offline
//! image, so the runtime compiles against this stub instead
//! (`use crate::xla_stub as xla;`).  The stub keeps the exact method
//! signatures the runtime calls:
//!
//! * [`PjRtClient::cpu`] **succeeds** (creating a client needs no
//!   artifacts), so engine startup proceeds far enough to produce
//!   accurate, artifact-specific error messages;
//! * everything that would actually parse HLO, compile, or execute
//!   returns [`XlaError`] with a clear "backend not linked" message.
//!
//! To restore real model execution: add the `xla` crate to
//! `rust/Cargo.toml`, delete this module, and change the runtime's
//! `use crate::xla_stub as xla;` back to the external crate.  No other
//! code changes are needed — the API below is a strict subset.

use std::fmt;

/// Error type standing in for the xla crate's error.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: the XLA/PJRT backend is not linked into this build \
         (offline stub — see rust/src/xla_stub.rs for how to enable it)"
    ))
}

/// Element types uploadable to / readable from device buffers.
pub trait ArrayElement: Copy {}

impl ArrayElement for i8 {}
impl ArrayElement for u8 {}
impl ArrayElement for i16 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (xla backend not linked)".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("compiling HLO"))
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(unavailable("host->device transfer"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable(&format!("parsing HLO text {path}")))
    }
}

/// Computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("executing"))
    }
}

/// Device-resident buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("device->host transfer"))
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(unavailable("unwrapping tuple"))
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable("reading literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_succeeds_but_execution_paths_error() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        assert!(c.buffer_from_host_buffer(&[1i32], &[1], None).is_err());
        let err = HloModuleProto::from_text_file("x.hlo.txt").err().unwrap();
        assert!(err.to_string().contains("not linked"), "{err}");
    }
}
