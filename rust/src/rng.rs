//! Deterministic PRNGs mirrored bit-for-bit with `python/compile/data.py`.
//!
//! crates.io is unreachable in this image, so `rand` is unavailable; a
//! splitmix64 stream (used for cross-language dataset generation — the
//! Rust workload generator must reproduce the Python-generated examples
//! exactly) plus a xoshiro256** generator (used where we just need good
//! local randomness, e.g. property tests and synthetic logits).

/// Sequential splitmix64. Mirrors `compile.data.SplitMix64` exactly.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` by modulo — matches the Python side, where the
    /// (negligible for n << 2^64) modulo bias is accepted for the sake of
    /// cross-language determinism.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// `true` with probability `num/den` (integer-exact across languages).
    #[inline]
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.below(den) < num
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna) seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)` (modulo; fine for test workloads).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// `true` with probability `num/den`.
    #[inline]
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.below(den) < num
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Random int8 logit, full range.
    #[inline]
    pub fn i8(&mut self) -> i8 {
        (self.next_u64() & 0xFF) as u8 as i8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // First three outputs for seed 0 (cross-checked against the Python
        // mirror; also the published splitmix64 reference sequence).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn below_is_in_range_and_deterministic() {
        let mut a = SplitMix64::new(1234);
        let mut b = SplitMix64::new(1234);
        for _ in 0..1000 {
            let x = a.below(17);
            assert!(x < 17);
            assert_eq!(x, b.below(17));
        }
    }

    #[test]
    fn xoshiro_streams_differ_by_seed() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
