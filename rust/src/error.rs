//! Minimal `anyhow`-compatible error handling (crates.io is unreachable
//! in this image, so `anyhow`/`thiserror` are unavailable).
//!
//! Provides the subset of the `anyhow` API this crate uses with the same
//! semantics:
//!
//! * [`Error`] — an opaque, context-carrying error value; notably it does
//!   **not** implement `std::error::Error`, which is what allows the
//!   blanket `From<E: std::error::Error>` conversion (the `?` operator on
//!   any standard error type), exactly like `anyhow::Error`.
//! * [`Result<T>`] — alias with the error type defaulted to [`Error`].
//! * [`Context`] — `.context(...)` / `.with_context(...)` on `Result` and
//!   `Option`, layering human-readable context onto the cause chain.
//! * [`anyhow!`] / [`bail!`] — ad-hoc error construction macros.
//!
//! Display behaviour matches `anyhow`: `{}` prints the outermost message
//! only, `{:#}` prints the whole chain separated by `": "`.

use std::fmt;

/// An opaque error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    /// Layer a new outermost context message onto the chain.
    pub fn push_context(mut self, c: impl fmt::Display) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The messages in the chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, `outer: inner: root`.
            for (i, m) in self.chain.iter().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(m)?;
            }
            Ok(())
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: message plus a "Caused by" trail, so
        // `.unwrap()`/`.expect()` failures stay readable.
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &self.chain[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

/// Blanket conversion from any standard error (enables `?`), capturing
/// its `source()` chain.  Sound for the same reason as in `anyhow`:
/// [`Error`] itself does not implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context layering for `Result` and `Option`, like `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

// Let callers write `use crate::error::{anyhow, bail}` even though
// `#[macro_export]` hoists the macros to the crate root.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("loading weights")
            .err()
            .unwrap();
        assert_eq!(format!("{e}"), "loading weights");
        assert_eq!(format!("{e:#}"), "loading weights: file gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err::<(), _>(io_err())?;
            Ok(())
        }
        let e = inner().err().unwrap();
        assert_eq!(format!("{e:#}"), "file gone");
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("missing value").err().unwrap();
        assert_eq!(format!("{e}"), "missing value");
        let e = anyhow!("bad {} at {}", "thing", 7);
        assert_eq!(format!("{e}"), "bad thing at 7");
        fn bails() -> Result<()> {
            bail!("stop: {}", 42);
        }
        assert_eq!(format!("{:#}", bails().err().unwrap()), "stop: 42");
    }

    #[test]
    fn with_context_layers_outermost_first() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading")
            .context("starting engine")
            .err()
            .unwrap();
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["starting engine", "reading", "file gone"]);
        assert_eq!(e.root_cause(), "file gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }
}
