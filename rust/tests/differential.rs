//! Differential-testing harness for the SIMD dispatch layer: every
//! vectorized kernel runs under **both** dispatch paths (forced scalar
//! and forced AVX2) on the same inputs and must agree bit-for-bit —
//! the AVX2 lane implementations are pinned to the scalar oracle, not
//! merely "close".
//!
//! Three layers of coverage:
//!
//! 1. **Kernel-level**: seeded random ragged shapes through the packed
//!    GEMM, the fused GEMM epilogues and their standalone
//!    requant/LayerNorm sweeps, the column-bounded `gemm_nt`/`gemm_pv`
//!    attention forms, and the HCCS batch engine (all four
//!    `OutputPath` × `Reciprocal` modes, masked and unmasked), with
//!    adversarial rows (all-negative, constant, max-at-tail) mixed
//!    into every tile.  On divergence the harness reports the first
//!    differing cell plus the full reproduction context (seed, shape,
//!    θ).
//! 2. **Golden vectors**: the committed `golden_vectors.json` oracle
//!    outputs must come back bit-exact from *both* paths — not just
//!    path-agreement but agreement with the numpy-derived ground truth.
//! 3. **Full model**: `forward_batch` logits are invariant across
//!    worker-pool sizes (1/2/8), across forced-scalar vs default
//!    dispatch, and across fused vs forced-unfused epilogue dataflows,
//!    and a panicking pool job propagates without poisoning the pool
//!    for subsequent GEMM passes.
//!
//! On hosts without AVX2 the path-agreement tests skip loudly (there is
//! only one path to run); the golden and pool tests still execute.

use hccs::hccs::{
    hccs_batch_into_with_path, hccs_batch_masked_into_with_path, HccsParams, OutputPath,
    Reciprocal,
};
use hccs::json::Value;
use hccs::linalg::{
    gemm_nt_bounded_into_with_path, gemm_pv_bounded_into_with_path, layernorm_rows_with_path,
    matmul_i8_ref, requant_with_path, scoped_fused, Epilogue, PackedGemm,
};
use hccs::model::{EncoderScratch, ModelConfig, NativeModel, SoftmaxBackend};
use hccs::rng::Xoshiro256;
use hccs::runtime::pool::{self, WorkerPool};
use hccs::simd::{self, SimdPath};
use std::panic::{catch_unwind, AssertUnwindSafe};

const MODES: [(&str, OutputPath, Reciprocal); 4] = [
    ("i16_div", OutputPath::I16, Reciprocal::Div),
    ("i16_clb", OutputPath::I16, Reciprocal::Clb),
    ("i8_div", OutputPath::I8, Reciprocal::Div),
    ("i8_clb", OutputPath::I8, Reciprocal::Clb),
];

/// Run `kernel` under both dispatch paths and assert bit-identical
/// output; on mismatch, panic with the first diverging cell and the
/// caller's full reproduction context.  Returns `false` (after a loud
/// skip message) when the host has no AVX2, so callers can count
/// effective coverage.
fn assert_paths_agree<F>(label: &str, ctx: &str, mut kernel: F) -> bool
where
    F: FnMut(SimdPath) -> Vec<i32>,
{
    if !simd::avx2_available() {
        eprintln!("SKIP {label}: AVX2 unavailable on this host (single-path)");
        return false;
    }
    let scalar = kernel(SimdPath::Scalar);
    let avx2 = kernel(SimdPath::Avx2);
    assert_eq!(
        scalar.len(),
        avx2.len(),
        "{label}: output lengths differ (scalar {} vs avx2 {})\n  context: {ctx}",
        scalar.len(),
        avx2.len()
    );
    if let Some(i) = (0..scalar.len()).find(|&i| scalar[i] != avx2[i]) {
        panic!(
            "{label}: paths diverge at cell {i}: scalar={} avx2={}\n  context: {ctx}",
            scalar[i], avx2[i]
        );
    }
    true
}

/// Random i8 tile with adversarial rows mixed in: row 0 all-negative
/// (horizontal-max zero-injection hazard), row 1 constant (Z at its
/// band edge), last row max-at-tail (remainder-lane handling).
fn adversarial_tile(rng: &mut Xoshiro256, rows: usize, cols: usize) -> Vec<i8> {
    let mut x: Vec<i8> = (0..rows * cols).map(|_| rng.i8()).collect();
    for v in x[..cols].iter_mut() {
        *v = -(v.unsigned_abs() as i8).max(1);
    }
    if rows > 1 {
        let c = rng.i8();
        x[cols..2 * cols].fill(c);
    }
    if rows > 2 {
        let last = x.len() - cols..x.len();
        for v in x[last.clone()].iter_mut() {
            *v = (*v).min(50);
        }
        x[rows * cols - 1] = 100;
    }
    x
}

#[test]
fn packed_gemm_paths_agree_on_seeded_ragged_shapes() {
    // Ragged on every axis: m around the MC=64 row-block edge, k odd
    // (the half-width madd tail), n off the NR=8 panel edge.
    let shapes = [
        (1usize, 1usize, 1usize),
        (2, 3, 8),
        (5, 7, 9),
        (16, 33, 24),
        (63, 64, 8),
        (64, 64, 64),
        (65, 129, 17),
        (130, 31, 40),
    ];
    let mut covered = false;
    for (seed, &(m, k, n)) in (0u64..).zip(shapes.iter()) {
        let mut rng = Xoshiro256::new(0xd1ff + seed);
        let x = adversarial_tile(&mut rng, m, k);
        let w: Vec<i8> = (0..n * k).map(|_| rng.i8()).collect();
        let packed = PackedGemm::pack(&w, n, k);
        let ctx = format!("packed GEMM seed={seed:#x} shape m={m} k={k} n={n}");
        // The scalar path itself is pinned to the reference oracle, so
        // path-agreement transitively pins AVX2 to the oracle too.
        let mut want = Vec::new();
        matmul_i8_ref(&x, k, &w, n, &mut want);
        let mut got = Vec::new();
        packed.gemm_into_with_path(SimdPath::Scalar, &x, &mut got);
        assert_eq!(got, want, "scalar packed GEMM vs reference oracle: {ctx}");
        covered |= assert_paths_agree("packed GEMM", &ctx, |path| {
            let mut out = Vec::new();
            packed.gemm_into_with_path(path, &x, &mut out);
            out
        });
    }
    if !covered {
        eprintln!("SKIP packed GEMM differential: no AVX2 (oracle checks still ran)");
    }
}

#[test]
fn nt_bounded_paths_agree_on_seeded_ragged_shapes() {
    // (m, n, kd) with the column bound sweeping 1 ..= n: the masked
    // attention form never reads past n_active B-rows.
    let shapes = [(1usize, 1usize, 8usize), (3, 5, 7), (5, 11, 35), (9, 16, 32), (17, 23, 64)];
    for (seed, &(m, n, kd)) in (0u64..).zip(shapes.iter()) {
        let mut rng = Xoshiro256::new(0x5eed + seed);
        let a = adversarial_tile(&mut rng, m, kd);
        for n_active in [1, n.div_ceil(2), n] {
            let b: Vec<i8> = (0..n_active * kd).map(|_| rng.i8()).collect();
            let ctx =
                format!("gemm_nt seed={seed:#x} m={m} n={n} n_active={n_active} kd={kd}");
            assert_paths_agree("gemm_nt_bounded", &ctx, |path| {
                let mut out = vec![0i32; m * n];
                gemm_nt_bounded_into_with_path(path, &a, &b, m, n, n_active, kd, &mut out);
                out
            });
        }
    }
}

#[test]
fn pv_bounded_paths_agree_on_seeded_ragged_shapes() {
    // p carries HCCS probabilities (0 ..= 32767) including exact zeros
    // (masked pads), v is i8; dv off the 8-lane edge exercises the
    // scalar tail.
    let shapes = [(1usize, 1usize, 1usize), (2, 9, 13), (5, 16, 8), (7, 33, 21), (16, 64, 40)];
    for (seed, &(m, c, dv)) in (0u64..).zip(shapes.iter()) {
        let mut rng = Xoshiro256::new(0xabcd + seed);
        for c_active in [1, c.div_ceil(2), c] {
            let p: Vec<i32> = (0..m * c)
                .map(|i| if i % 7 == 0 { 0 } else { rng.range_i64(0, 32767) as i32 })
                .collect();
            let v: Vec<i8> = (0..c_active * dv).map(|_| rng.i8()).collect();
            let ctx = format!("gemm_pv seed={seed:#x} m={m} c={c} c_active={c_active} dv={dv}");
            assert_paths_agree("gemm_pv_bounded", &ctx, |path| {
                let mut out = vec![0i32; m * dv];
                gemm_pv_bounded_into_with_path(path, &p, &v, m, c, c_active, dv, &mut out);
                out
            });
        }
    }
}

#[test]
fn requant_and_layernorm_paths_agree_on_adversarial_inputs() {
    // The standalone epilogue sweeps (unfused call sites: embeddings,
    // ctx requant, classifier pooling) run vectorized behind the same
    // dispatch — pin both paths on rail-heavy accumulators and on a
    // huge-magnitude row that forces the LN guard's scalar fallback.
    let shapes = [(1usize, 1usize), (3, 7), (5, 8), (13, 100), (64, 24)];
    for (seed, &(rows, d)) in (0u64..).zip(shapes.iter()) {
        let mut rng = Xoshiro256::new(0x9e97 + seed);
        let mut accs: Vec<i32> =
            (0..rows * d).map(|_| rng.range_i64(-2_000_000, 2_000_000) as i32).collect();
        for (i, rail) in [i32::MIN, i32::MAX, 0, -1, 1].into_iter().enumerate() {
            if i < accs.len() {
                accs[i] = rail;
            }
        }
        for div in [1i32, 3, 716, i32::MAX] {
            let ctx = format!("requant seed={seed:#x} rows={rows} d={d} div={div}");
            assert_paths_agree("requant", &ctx, |path| {
                let mut out = Vec::new();
                requant_with_path(path, &accs, div, &mut out);
                out.iter().map(|&v| i32::from(v)).collect()
            });
        }
        // LN inputs: residual-sum magnitudes (|v| ≤ 255) on most rows,
        // plus one row pushed past the vectorization guard.
        let mut x32: Vec<i32> = (0..rows * d).map(|_| rng.range_i64(-255, 255) as i32).collect();
        for v in x32[..d].iter_mut() {
            *v = rng.range_i64(-2_000_000, 2_000_000) as i32;
        }
        let gamma: Vec<i8> = (0..d).map(|_| 48 + rng.below(33) as i8).collect();
        let beta: Vec<i8> = (0..d).map(|_| (rng.below(17) as i64 - 8) as i8).collect();
        let ctx = format!("layernorm seed={seed:#x} rows={rows} d={d}");
        assert_paths_agree("layernorm_rows", &ctx, |path| {
            let mut out = Vec::new();
            layernorm_rows_with_path(path, &x32, d, &gamma, &beta, &mut out);
            out.iter().map(|&v| i32::from(v)).collect()
        });
    }
}

#[test]
fn fused_epilogue_paths_agree_on_seeded_shapes() {
    // The fused GEMM epilogue (requant → residual → LN applied per
    // MC-row block) through both dispatch paths, across the row-block
    // and panel edges.
    let shapes = [(1usize, 1usize, 1usize), (5, 7, 9), (64, 64, 24), (65, 33, 16), (130, 31, 40)];
    for (seed, &(m, k, n)) in (0u64..).zip(shapes.iter()) {
        let mut rng = Xoshiro256::new(0xf05e + seed);
        let x = adversarial_tile(&mut rng, m, k);
        let w: Vec<i8> = (0..n * k).map(|_| rng.i8()).collect();
        let packed = PackedGemm::pack(&w, n, k);
        let residual: Vec<i8> = (0..m * n).map(|_| rng.i8()).collect();
        let gamma: Vec<i8> = (0..n).map(|_| 48 + rng.below(33) as i8).collect();
        let beta: Vec<i8> = (0..n).map(|_| (rng.below(17) as i64 - 8) as i8).collect();
        let eps = [
            ("requant", Epilogue::Requant { div: 3 }),
            ("requant+relu", Epilogue::RequantRelu { div: 7 }),
            (
                "requant+res+ln",
                Epilogue::RequantResidualLn {
                    div: 713,
                    residual: &residual,
                    gamma: &gamma,
                    beta: &beta,
                },
            ),
        ];
        for (label, ep) in &eps {
            let ctx = format!("fused epilogue {label} seed={seed:#x} m={m} k={k} n={n}");
            assert_paths_agree("gemm_fused_into", &ctx, |path| {
                let mut out = Vec::new();
                packed.gemm_fused_into_with_path(path, &x, ep, &mut out);
                out.iter().map(|&v| i32::from(v)).collect()
            });
        }
    }
}

/// Mid-band feasible θ for a row width (the same derivation the golden
/// generator uses), shrinking `dmax` (then `s`) until the band is
/// non-empty — wide rows cap `B` at `32767/n`, which squeezes out
/// steep slopes.
fn mid_theta(mut s: i32, mut dmax: i32, n: usize) -> HccsParams {
    loop {
        if let Some((lo, hi)) = HccsParams::feasible_b_band(s, dmax, n) {
            return HccsParams::checked((lo + hi) / 2, s, dmax, n).expect("mid-band θ feasible");
        }
        if dmax > 1 {
            dmax /= 2;
        } else {
            assert!(s > 0, "no feasible θ at n={n}");
            s -= 1;
        }
    }
}

#[test]
fn hccs_batch_paths_agree_all_modes_on_seeded_shapes() {
    let shapes = [(1usize, 5usize), (3, 16), (4, 23), (2, 200), (65, 33), (8, 128)];
    for (seed, &(rows, cols)) in (0u64..).zip(shapes.iter()) {
        let mut rng = Xoshiro256::new(0xcc5 + seed);
        let x = adversarial_tile(&mut rng, rows, cols);
        let s = 1 + (seed as i32 % 4);
        let dmax = [16, 32, 64, 127][seed as usize % 4];
        let p = mid_theta(s, dmax, cols);
        for (mode, op, rc) in MODES {
            let ctx = format!(
                "hccs_batch seed={seed:#x} rows={rows} cols={cols} mode={mode} θ=({},{},{})",
                p.b, p.s, p.dmax
            );
            assert_paths_agree("hccs_batch", &ctx, |path| {
                let mut out = vec![0i32; rows * cols];
                hccs_batch_into_with_path(path, &x, rows, cols, &p, op, rc, &mut out);
                out
            });
        }
    }
}

#[test]
fn hccs_masked_paths_agree_all_modes_on_ragged_lengths() {
    // Lengths straddling the 16-lane stage-2 width and the 32-lane
    // stage-1 width, plus full-width and length-1 rows.
    let (rows, cols) = (6usize, 40usize);
    let lens = [1usize, 15, 16, 17, 40, 7];
    let mut rng = Xoshiro256::new(0x3a5c);
    let x = adversarial_tile(&mut rng, rows, cols);
    let p = mid_theta(2, 64, cols);
    for (mode, op, rc) in MODES {
        let ctx = format!("hccs_batch_masked rows={rows} cols={cols} lens={lens:?} mode={mode}");
        assert_paths_agree("hccs_batch_masked", &ctx, |path| {
            let mut out = vec![0i32; rows * cols];
            hccs_batch_masked_into_with_path(path, &x, rows, cols, &lens, &p, op, rc, &mut out);
            out
        });
    }
}

/// The committed numpy-oracle vectors must come back bit-exact from
/// **both** dispatch paths — ground-truth agreement, not just
/// path-agreement.  Runs the scalar leg even without AVX2.
#[test]
fn golden_vectors_pass_through_both_dispatch_paths() {
    let golden = Value::parse(include_str!("golden_vectors.json")).expect("golden parses");
    let paths: &[SimdPath] = if simd::avx2_available() {
        &[SimdPath::Scalar, SimdPath::Avx2]
    } else {
        eprintln!("SKIP golden AVX2 leg: unavailable on this host");
        &[SimdPath::Scalar]
    };
    let mut checked = 0usize;
    for case in golden.req("cases").as_arr().expect("cases") {
        let n = case.req("n").as_i64().unwrap() as usize;
        let x: Vec<i8> = case.req("x").flat_f64().iter().map(|&v| v as i8).collect();
        let p = HccsParams::checked(
            case.req("B").as_i64().unwrap() as i32,
            case.req("S").as_i64().unwrap() as i32,
            case.req("Dmax").as_i64().unwrap() as i32,
            n,
        )
        .expect("golden θ feasible");
        let Value::Obj(outs) = case.req("out") else { panic!("out must be an object") };
        for (mode, want_v) in outs {
            let (op, rc) = hccs::hccs::kernel::parse_mode(mode).unwrap();
            let want: Vec<i32> = want_v.flat_f64().iter().map(|&v| v as i32).collect();
            for &path in paths {
                let mut got = vec![0i32; n];
                hccs_batch_into_with_path(path, &x, 1, n, &p, op, rc, &mut got);
                assert_eq!(
                    got,
                    want,
                    "golden n={n} mode={mode} diverges on the {} path",
                    path.name()
                );
                checked += 1;
            }
        }
    }
    assert!(checked >= 80, "only {checked} golden vectors checked through dispatch");
}

fn batch_logits(model: &NativeModel, ids: &[i32], segs: &[i32]) -> Vec<i32> {
    let mut scratch = EncoderScratch::default();
    let backend = SoftmaxBackend::parse("i16_div").expect("known mode");
    model
        .forward_batch(ids, segs, backend, &mut scratch)
        .expect("forward_batch")
        .into_iter()
        .flat_map(|inf| inf.logits_i32)
        .collect()
}

fn bench_workload(model: &NativeModel, batch: usize) -> (Vec<i32>, Vec<i32>) {
    let mut generator = hccs::data::WorkloadGen::new(hccs::data::TaskKind::Sst2s, 11);
    let mut ids = Vec::with_capacity(batch * model.cfg.seq_len);
    let mut segs = Vec::with_capacity(batch * model.cfg.seq_len);
    for _ in 0..batch {
        let ex = generator.next_example();
        ids.extend_from_slice(&ex.ids);
        segs.extend_from_slice(&ex.segments);
    }
    (ids, segs)
}

/// `forward_batch` logits must be byte-identical whichever worker-pool
/// size executes the GEMM row blocks: blocks write disjoint output
/// regions, so thread count and claim order are invisible by
/// construction — this pins that claim end to end through the encoder.
#[test]
fn forward_batch_is_invariant_across_pool_sizes() {
    let task = hccs::data::TaskKind::Sst2s;
    let model = NativeModel::new(ModelConfig::bert_tiny(task), task, 42).expect("model build");
    let (ids, segs) = bench_workload(&model, 9);
    let reference = batch_logits(&model, &ids, &segs);
    assert!(!reference.is_empty());
    for threads in [1usize, 2, 8] {
        let p = WorkerPool::new(threads);
        let got = pool::with_pool(&p, || batch_logits(&model, &ids, &segs));
        assert_eq!(
            got, reference,
            "forward_batch logits changed under a {threads}-thread pool"
        );
    }
}

/// Forced-scalar dispatch must reproduce the default (possibly AVX2)
/// dispatch byte-for-byte on full-model logits.
#[test]
fn forward_batch_forced_scalar_matches_default_dispatch() {
    let task = hccs::data::TaskKind::Sst2s;
    let model = NativeModel::new(ModelConfig::bert_tiny(task), task, 42).expect("model build");
    let (ids, segs) = bench_workload(&model, 6);
    let default = batch_logits(&model, &ids, &segs);
    let forced = {
        let _guard = simd::scoped_override(SimdPath::Scalar);
        batch_logits(&model, &ids, &segs)
    };
    assert_eq!(forced, default, "forced-scalar logits differ from default dispatch");
}

/// The fused epilogue dataflow must reproduce the standalone-sweep
/// dataflow byte-for-byte on full-model logits — the `scoped_fused`
/// override is the in-process face of `HCCS_FORCE_UNFUSED=1`.
#[test]
fn forward_batch_fused_matches_forced_unfused() {
    let task = hccs::data::TaskKind::Sst2s;
    let model = NativeModel::new(ModelConfig::bert_tiny(task), task, 42).expect("model build");
    let (ids, segs) = bench_workload(&model, 6);
    let fused = {
        let _guard = scoped_fused(true);
        batch_logits(&model, &ids, &segs)
    };
    let unfused = {
        let _guard = scoped_fused(false);
        batch_logits(&model, &ids, &segs)
    };
    assert_eq!(fused, unfused, "fused epilogue logits differ from the unfused dataflow");
}

/// A panicking block propagates to the submitting thread and does NOT
/// poison the pool: the very next GEMM pass on the same pool is
/// correct.
#[test]
fn pool_panic_propagates_and_pool_stays_usable_for_gemm() {
    let p = WorkerPool::new(4);
    let boom = catch_unwind(AssertUnwindSafe(|| {
        p.run_blocks(8, &|i| {
            if i == 3 {
                panic!("differential-harness boom");
            }
        });
    }));
    assert!(boom.is_err(), "panic in a pool block must propagate to the caller");

    let mut rng = Xoshiro256::new(77);
    let (m, k, n) = (130usize, 33, 24);
    let x: Vec<i8> = (0..m * k).map(|_| rng.i8()).collect();
    let w: Vec<i8> = (0..n * k).map(|_| rng.i8()).collect();
    let packed = PackedGemm::pack(&w, n, k);
    let mut want = Vec::new();
    matmul_i8_ref(&x, k, &w, n, &mut want);
    let mut got = Vec::new();
    pool::with_pool(&p, || packed.gemm_into(&x, &mut got));
    assert_eq!(got, want, "pool produced a wrong GEMM after surviving a panic");
}
