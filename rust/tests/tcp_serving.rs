//! Integration tests for the TCP connection tier (`hccs::net`) over a
//! real loopback socket: persistent multi-request clients, torn writes,
//! mid-stream disconnects, wire garbage, deadline shedding — and
//! byte-parity of TCP `result` fields with the in-process serve loop.
//!
//! Every test body runs under [`with_timeout`] so a wedged reader or
//! writer thread fails the suite instead of hanging CI.  The whole file
//! is dispatch-agnostic and runs on both `HCCS_FORCE_SCALAR` legs.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use hccs::coordinator::BatchPolicy;
use hccs::data::TaskKind;
use hccs::json::Value;
use hccs::model::{
    DecoderScratch, ModelConfig, NativeBackend, NativeDecoder, NativeModel, NativeServeConfig,
    SoftmaxBackend,
};
use hccs::net::{NetConfig, TcpServer};
use hccs::server;
use hccs::tokenizer::Tokenizer;

/// Fail loudly instead of hanging: socket tests that deadlock (reader
/// waiting on a reply that never comes) must kill the suite.
fn with_timeout<F: FnOnce() + Send + 'static>(secs: u64, f: F) {
    let (tx, rx) = mpsc::channel();
    let body = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(panic) = body.join() {
                std::panic::resume_unwind(panic);
            }
        }
        Err(mpsc::RecvTimeoutError::Timeout) => panic!("test timed out after {secs}s"),
    }
}

/// One tiny calibrated model shared by every test in this binary
/// (construction calibrates per-head HCCS parameters, so do it once).
fn native_model() -> Arc<NativeModel> {
    static MODEL: OnceLock<Arc<NativeModel>> = OnceLock::new();
    MODEL
        .get_or_init(|| {
            let task = TaskKind::Sst2s;
            let cfg = ModelConfig {
                layers: 1,
                heads: 2,
                d_model: 32,
                d_ff: 64,
                seq_len: task.max_len(),
                vocab: hccs::data::VOCAB_SIZE as usize,
                n_classes: 2,
            };
            Arc::new(NativeModel::new(cfg, task, 42).unwrap())
        })
        .clone()
}

fn native_backend() -> Arc<NativeBackend> {
    Arc::new(
        NativeBackend::with_config(
            native_model(),
            SoftmaxBackend::parse("i16_div").unwrap(),
            NativeServeConfig {
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                shards: 2,
                length_bands: 1,
                max_in_flight: None,
            },
        )
        .unwrap(),
    )
}

fn tokenizer() -> Arc<Tokenizer> {
    Arc::new(Tokenizer::from_tokens(hccs::data::build_vocab()).unwrap())
}

/// One tiny calibrated decoder shared by the streaming tests (same
/// shapes as [`native_model`]; calibration is the expensive part).
fn native_decoder() -> Arc<NativeDecoder> {
    static DEC: OnceLock<Arc<NativeDecoder>> = OnceLock::new();
    DEC.get_or_init(|| {
        let task = TaskKind::Sst2s;
        let cfg = ModelConfig {
            layers: 1,
            heads: 2,
            d_model: 32,
            d_ff: 64,
            seq_len: task.max_len(),
            vocab: hccs::data::VOCAB_SIZE as usize,
            n_classes: 2,
        };
        Arc::new(NativeDecoder::new(cfg, task, 5).unwrap())
    })
    .clone()
}

/// A streaming-enabled tier: same classification substrate as
/// [`start_server`], plus decode sessions for `{"generate": ...}`.
fn start_streaming_server(cfg: NetConfig) -> (TcpServer, Arc<NativeBackend>) {
    let backend = Arc::new(
        NativeBackend::with_decoder(
            native_model(),
            native_decoder(),
            SoftmaxBackend::parse("i16_div").unwrap(),
            NativeServeConfig {
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                shards: 2,
                length_bands: 1,
                max_in_flight: None,
            },
        )
        .unwrap(),
    );
    let srv = TcpServer::start_streaming(
        backend.clone(),
        tokenizer(),
        TaskKind::Sst2s,
        "127.0.0.1:0",
        cfg,
    )
    .unwrap();
    (srv, backend)
}

fn start_server(cfg: NetConfig) -> (TcpServer, Arc<NativeBackend>) {
    let backend = native_backend();
    let srv =
        TcpServer::start(backend.clone(), tokenizer(), TaskKind::Sst2s, "127.0.0.1:0", cfg)
            .unwrap();
    (srv, backend)
}

/// Distinct in-vocab request texts (same word family as the shard
/// serving suite, so every request produces a real forward).
fn texts(n: usize) -> Vec<String> {
    (0..n)
        .map(|k| {
            format!(
                "w{:03} good{:02} not bad{:02} w{:03}",
                k % 40,
                k % 8,
                (k + 3) % 8,
                (40 - k) % 40
            )
        })
        .collect()
}

/// Reference replies from the in-process serve loop — the parity
/// baseline the TCP `result` fields must match byte-for-byte.
fn in_process_lines(texts: &[String]) -> Vec<String> {
    let backend = native_backend();
    let input = texts.join("\n") + "\n";
    let mut out: Vec<u8> = Vec::new();
    let n = server::serve(
        backend.as_ref(),
        &tokenizer(),
        TaskKind::Sst2s,
        input.as_bytes(),
        &mut out,
    )
    .unwrap();
    backend.shutdown();
    assert_eq!(n as usize, texts.len());
    String::from_utf8(out).unwrap().lines().map(str::to_string).collect()
}

/// Write `bytes` in `chunk`-sized slices so the server's reads observe
/// torn frames (every boundary, including mid-token and mid-string).
fn write_torn(stream: &mut TcpStream, bytes: &[u8], chunk: usize) {
    for c in bytes.chunks(chunk.max(1)) {
        stream.write_all(c).unwrap();
        stream.flush().unwrap();
    }
}

#[test]
fn tcp_replies_match_in_process_serve_across_concurrent_clients() {
    with_timeout(120, || {
        let reqs = texts(8);
        let expected = in_process_lines(&reqs);
        let (srv, backend) = start_server(NetConfig::default());
        let addr = srv.local_addr();

        // 4 persistent clients, each a full request/reply round trip per
        // request, each tearing its writes at a different grain.
        let clients: Vec<_> = [1usize, 2, 3, 7]
            .into_iter()
            .enumerate()
            .map(|(k, chunk)| {
                let (reqs, expected) = (reqs.clone(), expected.clone());
                std::thread::spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let mut replies = BufReader::new(stream.try_clone().unwrap());
                    for (i, text) in reqs.iter().enumerate() {
                        let id = (k * 100 + i) as u64;
                        let frame = format!("{{\"id\": {id}, \"text\": \"{text}\"}}\n");
                        write_torn(&mut stream, frame.as_bytes(), chunk);
                        let mut line = String::new();
                        assert!(replies.read_line(&mut line).unwrap() > 0, "reply {i}");
                        let v = Value::parse(line.trim()).unwrap();
                        assert_eq!(v.get("id").and_then(Value::as_i64), Some(id as i64));
                        assert!(v.get("error").is_none(), "client {k} req {i}: {line}");
                        assert_eq!(
                            v.get("result").and_then(Value::as_str),
                            Some(expected[i].as_str()),
                            "client {k} req {i}: TCP result must be byte-identical \
                             to the in-process serve line"
                        );
                    }
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }

        assert_eq!(srv.metrics.counter("net.connections").get(), 4);
        assert_eq!(srv.metrics.counter("net.requests").get(), 32);
        assert_eq!(srv.metrics.counter("net.replies").get(), 32);
        assert_eq!(
            srv.metrics.sum_counters("net.requests.conn"),
            32,
            "per-connection slot counters must roll up to the aggregate"
        );
        assert_eq!(srv.metrics.counter("net.frame_errors").get(), 0);
        srv.shutdown();
        backend.shutdown();
    });
}

#[test]
fn mid_stream_disconnect_leaves_other_connections_serving() {
    with_timeout(120, || {
        let reqs = texts(2);
        let expected = in_process_lines(&reqs);
        let (srv, backend) = start_server(NetConfig::default());
        let addr = srv.local_addr();

        // Client A: one good round trip, then vanish mid-frame.
        {
            let mut a = TcpStream::connect(addr).unwrap();
            let mut replies = BufReader::new(a.try_clone().unwrap());
            a.write_all(format!("{{\"text\": \"{}\"}}\n", reqs[0]).as_bytes()).unwrap();
            let mut line = String::new();
            assert!(replies.read_line(&mut line).unwrap() > 0);
            assert!(line.contains("\"result\""), "{line}");
            a.write_all(b"{\"text\": \"torn off mid-fra").unwrap();
            // Drop: the server sees EOF with a partial frame buffered.
        }

        // Client B on a fresh connection is unaffected.
        let mut b = TcpStream::connect(addr).unwrap();
        let mut replies = BufReader::new(b.try_clone().unwrap());
        b.write_all(format!("{{\"text\": \"{}\"}}\n", reqs[1]).as_bytes()).unwrap();
        let mut line = String::new();
        assert!(replies.read_line(&mut line).unwrap() > 0);
        let v = Value::parse(line.trim()).unwrap();
        assert_eq!(v.get("result").and_then(Value::as_str), Some(expected[1].as_str()));

        srv.shutdown();
        backend.shutdown();
    });
}

#[test]
fn garbage_on_the_wire_errors_the_connection_not_the_server() {
    with_timeout(120, || {
        let reqs = texts(1);
        let (srv, backend) = start_server(NetConfig::default());
        let addr = srv.local_addr();

        // Garbage between frames desynchronizes the stream: the server
        // answers with one framing error, then closes this connection.
        let mut bad = TcpStream::connect(addr).unwrap();
        let mut replies = BufReader::new(bad.try_clone().unwrap());
        bad.write_all(b"hello, this is not json\n").unwrap();
        let mut line = String::new();
        assert!(replies.read_line(&mut line).unwrap() > 0, "framing error reply expected");
        let v = Value::parse(line.trim()).unwrap();
        let err = v.get("error").and_then(Value::as_str).unwrap();
        assert!(err.contains("framing"), "{err}");
        assert_eq!(v.get("shed").and_then(Value::as_bool), Some(false));
        line.clear();
        assert_eq!(replies.read_line(&mut line).unwrap(), 0, "connection must close");

        // The listener and other connections keep serving.
        let mut ok = TcpStream::connect(addr).unwrap();
        let mut replies = BufReader::new(ok.try_clone().unwrap());
        ok.write_all(format!("{{\"text\": \"{}\"}}\n", reqs[0]).as_bytes()).unwrap();
        line.clear();
        assert!(replies.read_line(&mut line).unwrap() > 0);
        assert!(line.contains("\"result\""), "{line}");

        assert!(srv.metrics.counter("net.frame_errors").get() >= 1);
        srv.shutdown();
        backend.shutdown();
    });
}

#[test]
fn zero_deadline_sheds_every_request_with_shed_replies() {
    with_timeout(120, || {
        let (srv, backend) = start_server(NetConfig {
            deadline: Some(Duration::ZERO),
            ..NetConfig::default()
        });
        let addr = srv.local_addr();

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut replies = BufReader::new(stream.try_clone().unwrap());
        let n = 5;
        for (i, text) in texts(n).iter().enumerate() {
            stream
                .write_all(format!("{{\"id\": {i}, \"text\": \"{text}\"}}\n").as_bytes())
                .unwrap();
            let mut line = String::new();
            assert!(replies.read_line(&mut line).unwrap() > 0);
            let v = Value::parse(line.trim()).unwrap();
            assert_eq!(v.get("shed").and_then(Value::as_bool), Some(true), "{line}");
            let err = v.get("error").and_then(Value::as_str).unwrap();
            assert!(err.trim_start().starts_with("shed:"), "{err}");
        }
        drop(stream);
        drop(replies);

        assert_eq!(srv.metrics.counter("net.shed").get(), n as u64);
        assert_eq!(srv.metrics.counter("net.replies").get(), n as u64);
        srv.shutdown();
        backend.shutdown();
    });
}

#[test]
fn streaming_generate_matches_direct_decoder_and_stays_fifo() {
    with_timeout(120, || {
        let (srv, backend) = start_streaming_server(NetConfig::default());
        let addr = srv.local_addr();
        let mode = SoftmaxBackend::parse("i16_div").unwrap();

        // Reference tokens straight from the decoder on the same
        // prompt the server will tokenize from the wire text.
        let text = "w012 good03 w044";
        let tok = tokenizer();
        let enc =
            server::encode_request(&tok, TaskKind::Sst2s, text, TaskKind::Sst2s.max_len())
                .unwrap();
        let prompt = enc.ids[..enc.valid_len].to_vec();
        let mut scratch = DecoderScratch::default();
        let want = native_decoder().generate(&prompt, 6, mode, &mut scratch).unwrap();

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut replies = BufReader::new(stream.try_clone().unwrap());
        // The classification frame queues FIFO *behind* the stream: its
        // reply must arrive only after the stream's final frame.
        stream
            .write_all(
                format!(
                    "{{\"id\": 9, \"generate\": \"{text}\", \"max_new\": 6}}\n\
                     {{\"id\": 10, \"text\": \"{text}\"}}\n"
                )
                .as_bytes(),
            )
            .unwrap();

        let mut got = Vec::new();
        loop {
            let mut line = String::new();
            assert!(replies.read_line(&mut line).unwrap() > 0, "token frame");
            let v = Value::parse(line.trim()).unwrap();
            assert_eq!(v.get("id").and_then(Value::as_i64), Some(9), "{line}");
            assert!(v.get("error").is_none(), "{line}");
            let id = v.get("token_id").and_then(Value::as_i64).unwrap() as i32;
            got.push(id);
            assert_eq!(
                v.get("step").and_then(Value::as_i64),
                Some(got.len() as i64),
                "step counter must track the stream: {line}"
            );
            assert_eq!(
                v.get("token").and_then(Value::as_str),
                Some(tok.token(id)),
                "token text must match the vocab word for token_id: {line}"
            );
            if v.get("done").and_then(Value::as_bool) == Some(true) {
                break;
            }
        }
        assert_eq!(
            got, want.tokens,
            "TCP stream must carry exactly the direct greedy decode"
        );

        let mut line = String::new();
        assert!(replies.read_line(&mut line).unwrap() > 0, "classification reply");
        let v = Value::parse(line.trim()).unwrap();
        assert_eq!(v.get("id").and_then(Value::as_i64), Some(10));
        assert!(v.get("result").is_some(), "{line}");

        assert_eq!(srv.metrics.counter("net.streams").get(), 1);
        assert_eq!(srv.metrics.counter("net.stream_tokens").get(), got.len() as u64);
        srv.shutdown();
        backend.shutdown();
    });
}

/// Satellite regression test: `net.active` is RAII-guarded, so a
/// client that vanishes mid-stream (token frames still being written)
/// must still return the gauge to zero once its threads unwind.
#[test]
fn killing_a_connection_mid_stream_returns_the_active_gauge_to_zero() {
    with_timeout(120, || {
        let (srv, backend) = start_streaming_server(NetConfig::default());
        let addr = srv.local_addr();
        let gauge = srv.metrics.gauge("net.active");

        // Conn A opens a long stream, reads exactly one token frame,
        // then vanishes without reading the rest.
        let mut a = TcpStream::connect(addr).unwrap();
        let mut a_replies = BufReader::new(a.try_clone().unwrap());
        a.write_all(b"{\"id\": 1, \"generate\": \"w012 good03 w044\", \"max_new\": 64}\n")
            .unwrap();
        let mut line = String::new();
        assert!(a_replies.read_line(&mut line).unwrap() > 0, "first token frame");
        assert!(line.contains("\"token\""), "{line}");
        assert!(gauge.get() >= 1, "live connection must show in net.active");
        drop(a_replies);
        drop(a);

        // Conn B proves the tier still serves while A unwinds.
        let mut b = TcpStream::connect(addr).unwrap();
        let mut b_replies = BufReader::new(b.try_clone().unwrap());
        b.write_all(b"{\"id\": 2, \"text\": \"w012 good03\"}\n").unwrap();
        line.clear();
        assert!(b_replies.read_line(&mut line).unwrap() > 0);
        assert!(line.contains("\"result\""), "{line}");
        drop(b_replies);
        drop(b);

        // Both connections are gone; the RAII guards must bring the
        // gauge back to zero without a graceful server shutdown.
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while gauge.get() != 0 {
            assert!(
                std::time::Instant::now() < deadline,
                "net.active stuck at {} after both clients disconnected",
                gauge.get()
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(srv.metrics.counter("net.connections").get(), 2);
        assert_eq!(srv.metrics.counter("net.streams").get(), 1);
        srv.shutdown();
        backend.shutdown();
    });
}

#[test]
fn generate_frame_on_a_classify_only_server_is_a_per_request_error() {
    with_timeout(120, || {
        let (srv, backend) = start_server(NetConfig::default());
        let addr = srv.local_addr();

        let mut s = TcpStream::connect(addr).unwrap();
        let mut replies = BufReader::new(s.try_clone().unwrap());
        s.write_all(b"{\"id\": 5, \"generate\": \"w012 good03\"}\n").unwrap();
        let mut line = String::new();
        assert!(replies.read_line(&mut line).unwrap() > 0);
        let v = Value::parse(line.trim()).unwrap();
        assert_eq!(v.get("id").and_then(Value::as_i64), Some(5));
        let err = v.get("error").and_then(Value::as_str).unwrap();
        assert!(err.contains("--decode"), "{err}");
        assert_eq!(v.get("shed").and_then(Value::as_bool), Some(false));

        // Per-request error: the connection lives on.
        s.write_all(b"{\"id\": 6, \"text\": \"w012 good03\"}\n").unwrap();
        line.clear();
        assert!(replies.read_line(&mut line).unwrap() > 0);
        assert!(line.contains("\"result\""), "{line}");
        srv.shutdown();
        backend.shutdown();
    });
}

#[test]
fn zero_deadline_sheds_the_stream_with_a_shed_error_frame() {
    with_timeout(120, || {
        let (srv, backend) = start_streaming_server(NetConfig {
            deadline: Some(Duration::ZERO),
            ..NetConfig::default()
        });
        let addr = srv.local_addr();

        let mut s = TcpStream::connect(addr).unwrap();
        let mut replies = BufReader::new(s.try_clone().unwrap());
        s.write_all(b"{\"id\": 3, \"generate\": \"w012 good03\"}\n").unwrap();
        let mut line = String::new();
        assert!(replies.read_line(&mut line).unwrap() > 0);
        let v = Value::parse(line.trim()).unwrap();
        assert_eq!(v.get("id").and_then(Value::as_i64), Some(3));
        assert_eq!(v.get("shed").and_then(Value::as_bool), Some(true), "{line}");
        // The shed can land at admission (one plain error reply, no
        // stream opened) or on the queued prefill op (a stream error
        // frame carrying `step: 0`); both are a single shed error.
        if let Some(step) = v.get("step").and_then(Value::as_i64) {
            assert_eq!(step, 0, "shed before any token streamed: {line}");
        }
        let err = v.get("error").and_then(Value::as_str).unwrap();
        assert!(err.trim_start().starts_with("shed:"), "{err}");

        assert!(srv.metrics.counter("net.shed").get() >= 1);
        srv.shutdown();
        backend.shutdown();
    });
}

#[test]
fn poisoned_peer_mid_flight_leaves_concurrent_clients_unharmed() {
    with_timeout(120, || {
        // Three well-behaved clients pipeline requests WHILE a hostile
        // peer hammers the server with repeated garbage connections.
        // Every poisoned connection must die alone (one framing-error
        // reply, then close) — the regression this pins is a panicking
        // or poisoned connection thread taking the accept loop or a
        // sibling connection down with it.
        const GOOD_CLIENTS: usize = 3;
        const REQS_PER_CLIENT: usize = 4;
        const BAD_CONNS: usize = 5;
        let reqs = texts(GOOD_CLIENTS * REQS_PER_CLIENT);
        let expected = in_process_lines(&reqs);
        let (srv, backend) = start_server(NetConfig::default());
        let addr = srv.local_addr();

        let attacker = std::thread::spawn(move || {
            for _ in 0..BAD_CONNS {
                let mut bad = TcpStream::connect(addr).unwrap();
                let mut replies = BufReader::new(bad.try_clone().unwrap());
                bad.write_all(b"\x00\xffdefinitely not a frame\n").unwrap();
                let mut line = String::new();
                assert!(replies.read_line(&mut line).unwrap() > 0, "error reply expected");
                assert!(line.contains("\"error\""), "{line}");
                line.clear();
                assert_eq!(replies.read_line(&mut line).unwrap(), 0, "connection must close");
            }
        });

        let clients: Vec<_> = (0..GOOD_CLIENTS)
            .map(|c| {
                let mine: Vec<String> =
                    reqs[c * REQS_PER_CLIENT..(c + 1) * REQS_PER_CLIENT].to_vec();
                std::thread::spawn(move || {
                    let mut s = TcpStream::connect(addr).unwrap();
                    let mut replies = BufReader::new(s.try_clone().unwrap());
                    let mut out = Vec::new();
                    for text in &mine {
                        s.write_all(format!("{{\"text\": \"{text}\"}}\n").as_bytes()).unwrap();
                        let mut line = String::new();
                        assert!(replies.read_line(&mut line).unwrap() > 0);
                        let v = Value::parse(line.trim()).unwrap();
                        out.push(v.get("result").and_then(Value::as_str).unwrap().to_string());
                    }
                    out
                })
            })
            .collect();

        attacker.join().unwrap();
        for (c, h) in clients.into_iter().enumerate() {
            let got = h.join().unwrap();
            let want = &expected[c * REQS_PER_CLIENT..(c + 1) * REQS_PER_CLIENT];
            assert_eq!(got, want, "client {c} replies diverged");
        }

        assert!(srv.metrics.counter("net.frame_errors").get() >= BAD_CONNS as u64);
        let metrics = srv.metrics.clone();
        srv.shutdown();
        backend.shutdown();
        // shutdown() joins every connection thread, so the RAII gauge
        // guards have all dropped by the time it returns.
        assert_eq!(metrics.gauge("net.active").get(), 0, "live-connection gauge leaked");
    });
}
