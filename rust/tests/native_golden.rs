//! Artifact-free golden suite: the bit-exactness contract runs in CI
//! unconditionally (unlike `tests/golden.rs`, which needs `make
//! artifacts` and skips without it).
//!
//! `golden_vectors.json` is committed in-repo: 23 cases × all four
//! `OutputPath` × `Reciprocal` modes, generated once from the numpy
//! oracle (`python/compile/kernels/ref.py`) with every case re-derived
//! from the §III equations at generation time (see the file header and
//! the generator assertions).  Here each stored vector is checked
//! three ways:
//!
//! 1. against an **independent straight-line i64 oracle** reimplemented
//!    below (no shared code with `hccs::kernel`);
//! 2. bit-exactly against [`hccs_row`] (the scalar kernel);
//! 3. bit-exactly against [`hccs_batch`] (the batched engine, 1×n).

use hccs::hccs::kernel::parse_mode;
use hccs::hccs::{
    hccs_batch, hccs_batch_masked, hccs_row, hccs_rows_masked, HccsParams, OutputPath, Reciprocal,
};
use hccs::json::Value;

const GOLDEN: &str = include_str!("golden_vectors.json");

/// Straight-line i64 reimplementation of Algorithm 1 (§III).  Written
/// deliberately without reusing any kernel code: plain max, clamp,
/// affine score, sum, and the three reciprocal realizations, all in
/// i64 so any i32-range bug in the kernel would show as a mismatch.
fn oracle_row(x: &[i8], b: i64, s: i64, dmax: i64, op: OutputPath, rc: Reciprocal) -> Vec<i64> {
    let m = x.iter().map(|&v| i64::from(v)).max().expect("non-empty row");
    let scores: Vec<i64> = x
        .iter()
        .map(|&v| {
            let delta = (m - i64::from(v)).min(dmax);
            b - s * delta
        })
        .collect();
    assert!(scores.iter().all(|&v| v >= 0), "infeasible golden params");
    let z: i64 = scores.iter().sum();
    assert!(z > 0 && z <= 32767, "Z={z} outside the feasible band");
    let floor_log2 = |v: i64| 63 - v.leading_zeros() as i64;
    match (op, rc) {
        (OutputPath::I16, Reciprocal::Div) => {
            let rho = 32767 / z;
            scores.iter().map(|&v| v * rho).collect()
        }
        (OutputPath::I16, Reciprocal::Clb) => {
            let k = floor_log2(z);
            scores.iter().map(|&v| ((v * 32767) >> k).min(32767)).collect()
        }
        (OutputPath::I8, Reciprocal::Div) => {
            let rho8 = (255 << 15) / z;
            scores.iter().map(|&v| ((v * rho8) >> 15).min(255)).collect()
        }
        (OutputPath::I8, Reciprocal::Clb) => {
            let rho8 = (255 << 15) >> floor_log2(z);
            scores.iter().map(|&v| ((v * rho8) >> 15).min(255)).collect()
        }
    }
}

fn load_cases() -> Vec<Value> {
    let golden = Value::parse(GOLDEN).expect("golden_vectors.json must parse");
    golden.req("cases").as_arr().expect("cases array").to_vec()
}

#[test]
fn golden_suite_is_substantial() {
    let cases = load_cases();
    assert!(cases.len() >= 20, "only {} golden cases", cases.len());
    // Every case carries all four modes.
    for case in &cases {
        let Value::Obj(outs) = case.req("out") else {
            panic!("case.out must be an object")
        };
        assert_eq!(outs.len(), 4, "expected 4 modes per case");
        for mode in outs.keys() {
            parse_mode(mode).expect("known mode name");
        }
    }
}

#[test]
fn kernel_matches_committed_vectors_and_independent_oracle() {
    let mut checked = 0usize;
    for case in load_cases() {
        let n = case.req("n").as_i64().unwrap() as usize;
        let x: Vec<i8> = case.req("x").flat_f64().iter().map(|&v| v as i8).collect();
        assert_eq!(x.len(), n);
        let (b, s, dmax) = (
            case.req("B").as_i64().unwrap(),
            case.req("S").as_i64().unwrap(),
            case.req("Dmax").as_i64().unwrap(),
        );
        let p = HccsParams::checked(b as i32, s as i32, dmax as i32, n)
            .expect("golden params feasible");
        let Value::Obj(outs) = case.req("out") else { unreachable!() };
        for (mode, want_v) in outs {
            let (op, rc) = parse_mode(mode).unwrap();
            let want: Vec<i64> = want_v.flat_f64().iter().map(|&v| v as i64).collect();
            // 1. Independent i64 oracle agrees with the committed file.
            assert_eq!(oracle_row(&x, b, s, dmax, op, rc), want, "oracle n={n} {mode}");
            // 2. Scalar kernel is bit-exact.
            let got: Vec<i64> = hccs_row(&x, &p, op, rc).iter().map(|&v| i64::from(v)).collect();
            assert_eq!(got, want, "hccs_row n={n} {mode} θ=({b},{s},{dmax})");
            // 3. Batched engine is bit-exact on the same row.
            let batch: Vec<i64> =
                hccs_batch(&x, 1, n, &p, op, rc).iter().map(|&v| i64::from(v)).collect();
            assert_eq!(batch, want, "hccs_batch n={n} {mode}");
            checked += 1;
        }
    }
    assert!(checked >= 80, "only {checked} golden vectors checked");
}

fn load_masked_cases() -> Vec<Value> {
    let golden = Value::parse(GOLDEN).expect("golden_vectors.json must parse");
    golden.req("masked_cases").as_arr().expect("masked_cases array").to_vec()
}

/// Valid-length masked vectors: the masked engine must reproduce the
/// committed p̂ values — the active prefix equals the straight-line
/// oracle run on that prefix alone, and every pad column is **exactly
/// zero** (the hard mask, not the score floor).  Checked three ways,
/// like the dense suite: independent i64 oracle, masked batched
/// engine, and the prefix through the scalar row kernel.
#[test]
fn masked_kernel_matches_committed_vectors_and_oracle() {
    let cases = load_masked_cases();
    assert!(cases.len() >= 7, "only {} masked golden cases", cases.len());
    let mut checked = 0usize;
    for case in cases {
        let n = case.req("n").as_i64().unwrap() as usize;
        let len = case.req("len").as_i64().unwrap() as usize;
        assert!((1..=n).contains(&len));
        let x: Vec<i8> = case.req("x").flat_f64().iter().map(|&v| v as i8).collect();
        assert_eq!(x.len(), n);
        let (b, s, dmax) = (
            case.req("B").as_i64().unwrap(),
            case.req("S").as_i64().unwrap(),
            case.req("Dmax").as_i64().unwrap(),
        );
        let p = HccsParams::checked(b as i32, s as i32, dmax as i32, n)
            .expect("masked golden params feasible at full width");
        let Value::Obj(outs) = case.req("out") else { panic!("out must be an object") };
        assert_eq!(outs.len(), 4, "expected 4 modes per masked case");
        for (mode, want_v) in outs {
            let (op, rc) = parse_mode(mode).unwrap();
            let want: Vec<i64> = want_v.flat_f64().iter().map(|&v| v as i64).collect();
            assert_eq!(want.len(), n);
            assert!(want[len..].iter().all(|&v| v == 0), "committed pads nonzero");
            // 1. Independent oracle on the active prefix + zero pads.
            let mut oracle = oracle_row(&x[..len], b, s, dmax, op, rc);
            oracle.resize(n, 0);
            assert_eq!(oracle, want, "oracle n={n} len={len} {mode}");
            // 2. Masked batched engine is bit-exact, pads included.
            let got: Vec<i64> = hccs_batch_masked(&x, 1, n, &[len], &p, op, rc)
                .iter()
                .map(|&v| i64::from(v))
                .collect();
            assert_eq!(got, want, "hccs_batch_masked n={n} len={len} {mode}");
            // 3. The active prefix equals the scalar row kernel run on
            // the prefix alone (masking == truncation, bit for bit).
            let prefix: Vec<i64> =
                hccs_row(&x[..len], &p, op, rc).iter().map(|&v| i64::from(v)).collect();
            assert_eq!(prefix[..], want[..len], "prefix row kernel n={n} len={len} {mode}");
            // 4. The per-row grouped entry point (the decode step path)
            // is bit-exact too.
            let rows: Vec<i64> = hccs_rows_masked(&x, n, &[len], &[p], op, rc)
                .iter()
                .map(|&v| i64::from(v))
                .collect();
            assert_eq!(rows, want, "hccs_rows_masked n={n} len={len} {mode}");
            checked += 1;
        }
    }
    assert!(checked >= 28, "only {checked} masked golden vectors checked");
}

/// Satellite of the decode work: the single-key (`len = 1`, a causal
/// first step) and two-key (`len = 2`) edges must be pinned by
/// committed vectors in every mode — a 1-key row normalizes the lone
/// score `B` by `Z = B` itself, the shortest path through every
/// reciprocal realization.
#[test]
fn short_row_masked_cases_are_present() {
    let cases = load_masked_cases();
    for want_len in [1usize, 2] {
        let found = cases
            .iter()
            .filter(|c| c.req("len").as_i64() == Some(want_len as i64))
            .count();
        assert!(found >= 2, "need >= 2 masked golden cases at len={want_len}, have {found}");
    }
    // Hand-derived: len=2 prefix [90, 80] under θ=(300,4,64) → scores
    // 300/260, Z=560, ρ=⌊32767/560⌋=58 → p̂ = 17400 / 15080.
    let found = cases.iter().any(|case| {
        let x: Vec<i64> = case.req("x").flat_f64().iter().map(|&v| v as i64).collect();
        if case.req("len").as_i64() != Some(2) || x[0] != 90 || x[1] != 80 {
            return false;
        }
        let out: Vec<i64> =
            case.req("out").req("i16_div").flat_f64().iter().map(|&v| v as i64).collect();
        out[0] == 17400 && out[1] == 15080 && out[2..].iter().all(|&v| v == 0)
    });
    assert!(found, "hand-checked len=2 masked example missing from golden_vectors.json");
}

/// The masked file must contain the hand-derived masked worked example
/// (same guard as the dense suite: a broken regenerator can't slip by).
#[test]
fn hand_checked_masked_case_is_present() {
    // n=64 masked to len=16, θ=(300,4,64), x = all −100 except x0=90,
    // x7=80: m=90 → scores 300, 260, 44 over the 16 active columns;
    // Z = 300 + 260 + 14·44 = 1176; ρ = ⌊32767/1176⌋ = 27 →
    // p̂ = 8100 / 7020 / 1188, pads exactly 0.
    let found = load_masked_cases().iter().any(|case| {
        let x: Vec<i64> = case.req("x").flat_f64().iter().map(|&v| v as i64).collect();
        if x.len() != 64
            || x[0] != 90
            || x[7] != 80
            || x[1] != -100
            || case.req("len").as_i64() != Some(16)
        {
            return false;
        }
        let out: Vec<i64> =
            case.req("out").req("i16_div").flat_f64().iter().map(|&v| v as i64).collect();
        out[0] == 8100 && out[7] == 7020 && out[1] == 44 * 27 && out[16..].iter().all(|&v| v == 0)
    });
    assert!(found, "hand-checked masked example missing from golden_vectors.json");
}

/// The committed file must contain the §III worked example with the
/// hand-derived values (guards against regenerating the file with a
/// broken generator).
#[test]
fn hand_checked_case_is_present() {
    // n=64, θ=(300,4,64), x = all −100 except x0=90, x7=80:
    // m=90 → δ0=0, δ7=10, rest clamp at 64 → scores 300, 260, 44;
    // Z = 300 + 260 + 62·44 = 3288; ρ = ⌊32767/3288⌋ = 9.
    let cases = load_cases();
    let found = cases.iter().any(|case| {
        let x: Vec<i64> = case.req("x").flat_f64().iter().map(|&v| v as i64).collect();
        if x.len() != 64 || x[0] != 90 || x[7] != 80 || x[1] != -100 {
            return false;
        }
        let out: Vec<i64> =
            case.req("out").req("i16_div").flat_f64().iter().map(|&v| v as i64).collect();
        out[0] == 300 * 9 && out[7] == 260 * 9 && out[1] == 44 * 9
    });
    assert!(found, "hand-checked worked example missing from golden_vectors.json");
}
