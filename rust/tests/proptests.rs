//! Property-based tests (proptest_lite) on the coordinator, kernel,
//! attention, native-encoder, and streaming-framer invariants called
//! out in DESIGN.md §7.

use std::time::{Duration, Instant};

use hccs::coordinator::{BatchPolicy, DynamicBatcher};
use hccs::data::{TaskKind, WorkloadGen};
use hccs::hccs::attention::{hccs_attention, AttentionInputs, AttentionScratch};
use hccs::hccs::{
    hccs_batch, hccs_batch_masked, hccs_row, hccs_row_into, HccsParams, OutputPath, Reciprocal,
    T_I16, T_I8,
};
use hccs::json::{FrameLimits, StreamingFramer};
use hccs::linalg::{dot_i8, gemm_nt_into, gemm_pv_into, matmul_i8_ref, scoped_fused, PackedGemm};
use hccs::model::{
    DecoderScratch, EncoderScratch, ModelConfig, NativeDecoder, NativeModel, SoftmaxBackend,
};
use hccs::proptest_lite::{check, shrink_int, Config};
use hccs::rng::Xoshiro256;
use hccs::simd::{scoped_override, SimdPath};

/// Serializes the two fused-epilogue properties: the fused override is
/// a process-wide atomic (like the SIMD override, flipping it changes
/// *which* code computes a result, never the result), so the tests that
/// compare the two legs take this lock to keep each comparison
/// meaningful rather than racing each other's guards.
static FUSED_TOGGLE: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Draw a feasible θ uniformly from the Eq. (11) region for length n.
fn feasible_theta(rng: &mut Xoshiro256, n: usize) -> HccsParams {
    loop {
        let dmax = rng.range_i64(1, 127) as i32;
        let s = rng.range_i64(0, 16) as i32;
        if let Some((lo, hi)) = HccsParams::feasible_b_band(s, dmax, n) {
            let b = rng.range_i64(lo as i64, hi as i64) as i32;
            return HccsParams::checked(b, s, dmax, n).unwrap();
        }
    }
}

#[derive(Clone, Debug)]
struct RowCase {
    x: Vec<i8>,
    theta: HccsParams,
}

fn gen_row(rng: &mut Xoshiro256) -> RowCase {
    let n = *[2usize, 3, 8, 32, 64, 128, 200, 256]
        .get(rng.below(8) as usize)
        .unwrap();
    let theta = feasible_theta(rng, n);
    let x = (0..n).map(|_| rng.i8()).collect();
    RowCase { x, theta }
}

fn shrink_row(c: &RowCase) -> Vec<RowCase> {
    let mut out = Vec::new();
    if c.x.len() > 2 {
        let half = c.x[..c.x.len() / 2].to_vec();
        // Re-validate θ for the shorter row; keep only if still feasible.
        if c.theta.validate(half.len()).is_ok() {
            out.push(RowCase { x: half, theta: c.theta });
        }
    }
    let mut zeroed = c.clone();
    if zeroed.x.iter().any(|&v| v != 0) {
        for v in zeroed.x.iter_mut() {
            *v /= 2;
        }
        out.push(zeroed);
    }
    out
}

/// For every feasible θ and every int8 row: all four HCCS modes produce
/// non-negative, bounded, rank-preserving output whose sum is close to T.
#[test]
fn prop_hccs_simplex_and_order() {
    check(
        "hccs-simplex-order",
        Config { cases: 400, ..Default::default() },
        gen_row,
        shrink_row,
        |case| {
            let n = case.x.len();
            for (op, rc, t) in [
                (OutputPath::I16, Reciprocal::Div, T_I16),
                (OutputPath::I16, Reciprocal::Clb, T_I16),
                (OutputPath::I8, Reciprocal::Div, T_I8),
                (OutputPath::I8, Reciprocal::Clb, T_I8),
            ] {
                let p = hccs_row(&case.x, &case.theta, op, rc);
                if p.iter().any(|&v| v < 0) {
                    return Err(format!("negative output under {op:?}/{rc:?}"));
                }
                if p.iter().any(|&v| v > t) {
                    return Err(format!("output exceeds T={t} under {op:?}/{rc:?}"));
                }
                // Rank preservation: x_i > x_j ⇒ p_i >= p_j.
                for i in 0..n {
                    for j in 0..n {
                        if case.x[i] > case.x[j] && p[i] < p[j] {
                            return Err(format!(
                                "rank violated under {op:?}/{rc:?}: x[{i}]={} > x[{j}]={} but p {} < {}",
                                case.x[i], case.x[j], p[i], p[j]
                            ));
                        }
                    }
                }
                // Divide paths keep Σp̂ within (T - Z, T] (truncation only).
                if rc == Reciprocal::Div && op == OutputPath::I16 {
                    let sum: i64 = p.iter().map(|&v| v as i64).sum();
                    if sum > t as i64 {
                        return Err(format!("i16 sum {sum} > T"));
                    }
                    if sum * 2 < t as i64 {
                        return Err(format!("i16 sum {sum} < T/2 — over-lossy"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Equal inputs must receive equal probabilities (lane symmetry).
#[test]
fn prop_hccs_symmetry() {
    check(
        "hccs-symmetry",
        Config { cases: 300, ..Default::default() },
        gen_row,
        shrink_row,
        |case| {
            let p = hccs_row(&case.x, &case.theta, OutputPath::I16, Reciprocal::Div);
            for i in 0..case.x.len() {
                for j in (i + 1)..case.x.len() {
                    if case.x[i] == case.x[j] && p[i] != p[j] {
                        return Err(format!("x[{i}]==x[{j}] but p {} != {}", p[i], p[j]));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Shifting every logit by a constant must not change the output
/// (max-centering invariance, paper §III: only distances from the row
/// max enter the surrogate) — for **all four** kernel modes, as long
/// as values stay in int8.
#[test]
fn prop_hccs_shift_invariance() {
    check(
        "hccs-shift-invariance",
        Config { cases: 300, ..Default::default() },
        |rng| {
            let mut c = gen_row(rng);
            // Confine logits so a shift of ±16 cannot clip.
            for v in c.x.iter_mut() {
                *v = (*v / 2).clamp(-100, 100);
            }
            (c, rng.range_i64(-16, 16) as i8)
        },
        |_| vec![],
        |(case, shift)| {
            let shifted: Vec<i8> = case.x.iter().map(|&v| v + shift).collect();
            for (op, rc) in [
                (OutputPath::I16, Reciprocal::Div),
                (OutputPath::I16, Reciprocal::Clb),
                (OutputPath::I8, Reciprocal::Div),
                (OutputPath::I8, Reciprocal::Clb),
            ] {
                let a = hccs_row(&case.x, &case.theta, op, rc);
                let b = hccs_row(&shifted, &case.theta, op, rc);
                if a != b {
                    return Err(format!(
                        "output changed under constant shift {shift} ({op:?}/{rc:?})"
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Fused attention invariants
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct AttnCase {
    q: Vec<i8>,
    k: Vec<i8>,
    v: Vec<i8>,
    r: usize,
    c: usize,
    dk: usize,
    dv: usize,
    theta: HccsParams,
    perm: Vec<usize>,
    scale_den: i32,
}

fn gen_attn(rng: &mut Xoshiro256) -> AttnCase {
    let r = 1 + rng.below(6) as usize;
    let c = 2 + rng.below(31) as usize;
    let dk = 1 + rng.below(16) as usize;
    let dv = 1 + rng.below(8) as usize;
    let theta = feasible_theta(rng, c);
    let gen = |n: usize, rng: &mut Xoshiro256| -> Vec<i8> {
        (0..n).map(|_| (rng.below(61) as i64 - 30) as i8).collect()
    };
    let q = gen(r * dk, rng);
    let k = gen(c * dk, rng);
    let v = gen(c * dv, rng);
    // Fisher-Yates permutation of the key/value rows.
    let mut perm: Vec<usize> = (0..c).collect();
    for i in (1..c).rev() {
        perm.swap(i, rng.below(i as u64 + 1) as usize);
    }
    AttnCase { q, k, v, r, c, dk, dv, theta, perm, scale_den: 1 + rng.below(32) as i32 }
}

/// Attention is permutation-equivariant over key/value rows: applying
/// the same permutation to K's and V's rows leaves `p̂ @ V` unchanged
/// (row max, Z, and the per-key probabilities all travel with the
/// permutation) — for every kernel mode.
#[test]
fn prop_attention_key_value_permutation_equivariance() {
    check(
        "attention-kv-permutation",
        Config { cases: 200, ..Default::default() },
        gen_attn,
        |_| vec![],
        |case| {
            let mut kp = vec![0i8; case.k.len()];
            let mut vp = vec![0i8; case.v.len()];
            for (dst, &src) in case.perm.iter().enumerate() {
                kp[dst * case.dk..(dst + 1) * case.dk]
                    .copy_from_slice(&case.k[src * case.dk..(src + 1) * case.dk]);
                vp[dst * case.dv..(dst + 1) * case.dv]
                    .copy_from_slice(&case.v[src * case.dv..(src + 1) * case.dv]);
            }
            let base = AttentionInputs {
                q: &case.q,
                k: &case.k,
                v: &case.v,
                r: case.r,
                c: case.c,
                dk: case.dk,
                dv: case.dv,
            };
            let permuted = AttentionInputs { k: &kp, v: &vp, ..base.clone() };
            let mut scratch = AttentionScratch::default();
            let mut out_a = vec![0i32; case.r * case.dv];
            let mut out_b = vec![0i32; case.r * case.dv];
            for (op, rc) in [
                (OutputPath::I16, Reciprocal::Div),
                (OutputPath::I16, Reciprocal::Clb),
                (OutputPath::I8, Reciprocal::Div),
                (OutputPath::I8, Reciprocal::Clb),
            ] {
                hccs_attention(
                    &base,
                    &case.theta,
                    op,
                    rc,
                    1,
                    case.scale_den,
                    &mut scratch,
                    &mut out_a,
                )
                .map_err(|e| format!("base attention failed: {e}"))?;
                hccs_attention(
                    &permuted,
                    &case.theta,
                    op,
                    rc,
                    1,
                    case.scale_den,
                    &mut scratch,
                    &mut out_b,
                )
                .map_err(|e| format!("permuted attention failed: {e}"))?;
                if out_a != out_b {
                    return Err(format!(
                        "p̂·V changed under K/V row permutation ({op:?}/{rc:?})"
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// linalg GEMM core vs the scalar oracle
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct GemmCase {
    rows: usize,
    d_in: usize,
    d_out: usize,
    x: Vec<i8>,
    w: Vec<i8>,
}

fn gen_gemm(rng: &mut Xoshiro256) -> GemmCase {
    // Ragged everywhere: rows crossing the MC=64 block edge, d_out
    // crossing the NR=8 panel edge, sub-lane and wide d_in.
    let rows = 1 + rng.below(80) as usize;
    let d_in = 1 + rng.below(70) as usize;
    let d_out = 1 + rng.below(40) as usize;
    let x = (0..rows * d_in).map(|_| rng.i8()).collect();
    let w = (0..d_out * d_in).map(|_| rng.i8()).collect();
    GemmCase { rows, d_in, d_out, x, w }
}

fn shrink_gemm(c: &GemmCase) -> Vec<GemmCase> {
    let mut out = Vec::new();
    if c.rows > 1 {
        let rows = c.rows / 2;
        out.push(GemmCase { rows, x: c.x[..rows * c.d_in].to_vec(), ..c.clone() });
    }
    if c.d_out > 1 {
        let d_out = c.d_out / 2;
        out.push(GemmCase { d_out, w: c.w[..d_out * c.d_in].to_vec(), ..c.clone() });
    }
    out
}

/// The packed, panel-tiled GEMM must be bit-exact with the scalar
/// reference oracle on every ragged shape — this is what lets the whole
/// encoder ride on it without moving a single logit.
#[test]
fn prop_packed_gemm_bit_exact_with_scalar_oracle() {
    check(
        "packed-gemm-vs-oracle",
        Config { cases: 200, ..Default::default() },
        gen_gemm,
        shrink_gemm,
        |case| {
            let packed = PackedGemm::pack(&case.w, case.d_out, case.d_in);
            let (mut got, mut want) = (Vec::new(), Vec::new());
            packed.gemm_into(&case.x, &mut got);
            matmul_i8_ref(&case.x, case.d_in, &case.w, case.d_out, &mut want);
            if got != want {
                let bad = got.iter().zip(&want).position(|(a, b)| a != b).unwrap_or(0);
                return Err(format!(
                    "packed GEMM diverged at flat index {bad} (row {}, unit {}): {} != {}",
                    bad / case.d_out,
                    bad % case.d_out,
                    got[bad],
                    want[bad]
                ));
            }
            Ok(())
        },
    );
}

/// The A·Bᵀ and p̂·V kernels must agree with their per-cell scalar
/// compositions on ragged shapes (remainder columns, zero-probability
/// rows).
#[test]
fn prop_nt_and_pv_kernels_match_scalar() {
    check(
        "nt-pv-vs-scalar",
        Config { cases: 200, ..Default::default() },
        |rng| {
            let m = 1 + rng.below(10) as usize;
            let n = 1 + rng.below(13) as usize;
            let kd = 1 + rng.below(24) as usize;
            let dv = 1 + rng.below(9) as usize;
            let a: Vec<i8> = (0..m * kd).map(|_| rng.i8()).collect();
            let b: Vec<i8> = (0..n * kd).map(|_| rng.i8()).collect();
            let v: Vec<i8> = (0..n * dv).map(|_| rng.i8()).collect();
            let p: Vec<i32> = (0..m * n)
                .map(|_| if rng.below(4) == 0 { 0 } else { rng.range_i64(0, 1000) as i32 })
                .collect();
            (m, n, kd, dv, a, b, v, p)
        },
        |_| vec![],
        |(m, n, kd, dv, a, b, v, p)| {
            let (m, n, kd, dv) = (*m, *n, *kd, *dv);
            let mut nt = vec![0i32; m * n];
            gemm_nt_into(a, b, m, n, kd, &mut nt);
            for i in 0..m {
                for j in 0..n {
                    let want = dot_i8(&a[i * kd..(i + 1) * kd], &b[j * kd..(j + 1) * kd]);
                    if nt[i * n + j] != want {
                        return Err(format!("NT cell ({i},{j}): {} != {want}", nt[i * n + j]));
                    }
                }
            }
            let mut pv = vec![0i32; m * dv];
            gemm_pv_into(p, v, m, n, dv, &mut pv);
            for i in 0..m {
                for t in 0..dv {
                    let want: i32 = (0..n).map(|j| p[i * n + j] * i32::from(v[j * dv + t])).sum();
                    if pv[i * dv + t] != want {
                        return Err(format!("PV cell ({i},{t}): {} != {want}", pv[i * dv + t]));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Batch-axis equivalence of the native encoder
// ---------------------------------------------------------------------------

/// `forward_batch` must be bit-exact with per-example `forward` for
/// every softmax backend (all four HCCS modes + the f32 reference),
/// every batch composition, and with a *reused* scratch that has
/// already seen other batch sizes — the property that makes the sharded
/// `NativeBackend`'s dynamic batching bit-drift-free by construction.
#[test]
fn prop_forward_batch_bit_exact_with_single_forward() {
    let task = TaskKind::Sst2s;
    let cfg = ModelConfig {
        layers: 2,
        heads: 2,
        d_model: 32,
        d_ff: 64,
        seq_len: task.max_len(),
        vocab: hccs::data::VOCAB_SIZE as usize,
        n_classes: 2,
    };
    // One model for every case (construction/calibration dominates).
    let model = NativeModel::new(cfg, task, 7).expect("model build");
    let backends: Vec<SoftmaxBackend> = std::iter::once(SoftmaxBackend::F32Ref)
        .chain(SoftmaxBackend::hccs_modes())
        .collect();
    check(
        "forward-batch-bit-exact",
        Config { cases: 8, ..Default::default() },
        |rng| {
            // Two batches of different sizes run back to back through
            // the same scratch (mixed sizes + scratch reuse).
            (rng.below(u64::MAX), 1 + rng.below(5) as usize, 1 + rng.below(5) as usize)
        },
        |_| vec![],
        |&(input_seed, bs_a, bs_b)| {
            let mut generator = WorkloadGen::new(task, input_seed);
            let examples: Vec<_> = (0..bs_a + bs_b).map(|_| generator.next_example()).collect();
            let mut batch_scratch = EncoderScratch::default();
            let mut single_scratch = EncoderScratch::default();
            for backend in &backends {
                for (lo, hi) in [(0, bs_a), (bs_a, bs_a + bs_b)] {
                    let batch = &examples[lo..hi];
                    let mut ids = Vec::new();
                    let mut segs = Vec::new();
                    for ex in batch {
                        ids.extend_from_slice(&ex.ids);
                        segs.extend_from_slice(&ex.segments);
                    }
                    let stacked = model
                        .forward_batch(&ids, &segs, *backend, &mut batch_scratch)
                        .map_err(|e| format!("forward_batch: {e}"))?;
                    if stacked.len() != batch.len() {
                        return Err(format!(
                            "{} inferences for {} examples",
                            stacked.len(),
                            batch.len()
                        ));
                    }
                    for (i, (inf, ex)) in stacked.iter().zip(batch).enumerate() {
                        let single = model
                            .forward(&ex.ids, &ex.segments, *backend, &mut single_scratch)
                            .map_err(|e| format!("forward: {e}"))?;
                        if inf.logits_i32 != single.logits_i32
                            || inf.predicted != single.predicted
                            || inf.logits != single.logits
                        {
                            return Err(format!(
                                "batch[{i}] diverged from single forward under {} \
                                 (batch size {}): {:?} vs {:?}",
                                backend.name(),
                                batch.len(),
                                inf.logits_i32,
                                single.logits_i32
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Padding invariance of the native encoder (the valid-length contract)
// ---------------------------------------------------------------------------

/// The same example, padded to different `max_len` values, must produce
/// **bit-identical** logits under all four HCCS modes and the f32
/// reference — the load-bearing contract of the valid-length masked
/// stack.  Before masking this was impossible: the clipped-linear score
/// floor `B - S·Dmax` is deliberately positive, so every extra `[PAD]`
/// column received probability mass and shifted the mix.
#[test]
fn prop_padding_invariance_bit_identical_logits() {
    let task = TaskKind::Sst2s;
    let cfg = ModelConfig {
        layers: 2,
        heads: 2,
        d_model: 32,
        d_ff: 64,
        seq_len: task.max_len(),
        vocab: hccs::data::VOCAB_SIZE as usize,
        n_classes: 2,
    };
    let model = NativeModel::new(cfg, task, 17).expect("model build");
    let backends: Vec<SoftmaxBackend> = std::iter::once(SoftmaxBackend::F32Ref)
        .chain(SoftmaxBackend::hccs_modes())
        .collect();
    let seq = cfg.seq_len;
    check(
        "padding-invariance",
        Config { cases: 10, ..Default::default() },
        |rng| (rng.below(u64::MAX), rng.below(u64::MAX)),
        |_| vec![],
        |&(input_seed, pad_seed)| {
            let mut generator = WorkloadGen::new(task, input_seed);
            let ex = std::iter::repeat_with(|| generator.next_example())
                .find(|ex| ex.valid_len < seq)
                .expect("generator yields a padded example");
            // Candidate paddings: the bare example, one extra pad, two
            // random intermediates, and the full task width.
            let mut rng = Xoshiro256::new(pad_seed);
            let span = (seq - ex.valid_len) as u64;
            let mut pads = vec![ex.valid_len, ex.valid_len + 1, seq];
            pads.push(ex.valid_len + rng.below(span + 1) as usize);
            pads.push(ex.valid_len + rng.below(span + 1) as usize);
            let mut scratch = EncoderScratch::default();
            for backend in &backends {
                let base = model
                    .forward(&ex.ids[..pads[0]], &ex.segments[..pads[0]], *backend, &mut scratch)
                    .map_err(|e| format!("forward at pad {}: {e}", pads[0]))?;
                for &pad_to in &pads[1..] {
                    let inf = model
                        .forward(&ex.ids[..pad_to], &ex.segments[..pad_to], *backend, &mut scratch)
                        .map_err(|e| format!("forward at pad {pad_to}: {e}"))?;
                    if inf.logits_i32 != base.logits_i32
                        || inf.predicted != base.predicted
                        || inf.logits != base.logits
                    {
                        return Err(format!(
                            "{} diverged between pad {} and pad {pad_to} \
                             (valid_len {}): {:?} vs {:?}",
                            backend.name(),
                            pads[0],
                            ex.valid_len,
                            base.logits_i32,
                            inf.logits_i32
                        ));
                    }
                }
            }
            // Batch composition with mixed paddings is equally inert:
            // stack the example at full width next to itself and check
            // against the unpadded single forward.
            let mut ids = ex.ids.clone();
            ids.extend_from_slice(&ex.ids);
            let mut segs = ex.segments.clone();
            segs.extend_from_slice(&ex.segments);
            let batch = model
                .forward_batch(&ids, &segs, SoftmaxBackend::hccs_modes()[0], &mut scratch)
                .map_err(|e| format!("forward_batch: {e}"))?;
            let single = model
                .forward(
                    &ex.ids[..ex.valid_len],
                    &ex.segments[..ex.valid_len],
                    SoftmaxBackend::hccs_modes()[0],
                    &mut scratch,
                )
                .map_err(|e| format!("single forward: {e}"))?;
            for inf in &batch {
                if inf.logits_i32 != single.logits_i32 {
                    return Err("batched padded example diverged from bare example".into());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Fused GEMM epilogues vs the standalone-sweep dataflow
// ---------------------------------------------------------------------------

/// The fused epilogue path (requant / residual-add / integer LayerNorm
/// applied per MC row block inside `PackedGemm`) must be **bit-exact**
/// with the standalone-sweep dataflow it replaced
/// (`HCCS_FORCE_UNFUSED=1`), for all four HCCS modes, on both SIMD
/// dispatch legs, across mixed batch sizes with ragged valid lengths
/// and a reused scratch.  This is the contract that makes the fusion a
/// pure dataflow change: same integers, fewer full-tile passes.
#[test]
fn prop_fused_path_bit_exact_with_forced_unfused() {
    let task = TaskKind::Sst2s;
    let cfg = ModelConfig {
        layers: 2,
        heads: 2,
        d_model: 32,
        d_ff: 64,
        seq_len: task.max_len(),
        vocab: hccs::data::VOCAB_SIZE as usize,
        n_classes: 2,
    };
    let model = NativeModel::new(cfg, task, 7).expect("model build");
    // Both dispatch legs when the host has AVX2; twice scalar otherwise
    // (the second leg is then redundant but still correct).
    let legs = [hccs::simd::active(), SimdPath::Scalar];
    let lock = FUSED_TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    check(
        "fused-vs-forced-unfused",
        Config { cases: 6, ..Default::default() },
        |rng| (rng.below(u64::MAX), 1 + rng.below(4) as usize, 1 + rng.below(4) as usize),
        |_| vec![],
        |&(input_seed, bs_a, bs_b)| {
            let mut generator = WorkloadGen::new(task, input_seed);
            let examples: Vec<_> = (0..bs_a + bs_b).map(|_| generator.next_example()).collect();
            let mut scratch = EncoderScratch::default();
            for &leg in &legs {
                let _simd = scoped_override(leg);
                for backend in SoftmaxBackend::hccs_modes() {
                    // Two batch sizes back to back through the same
                    // scratch, each run fused then forced-unfused.
                    for (lo, hi) in [(0, bs_a), (bs_a, bs_a + bs_b)] {
                        let batch = &examples[lo..hi];
                        let mut ids = Vec::new();
                        let mut segs = Vec::new();
                        for ex in batch {
                            ids.extend_from_slice(&ex.ids);
                            segs.extend_from_slice(&ex.segments);
                        }
                        let fused = {
                            let _g = scoped_fused(true);
                            model
                                .forward_batch(&ids, &segs, backend, &mut scratch)
                                .map_err(|e| format!("fused forward_batch: {e}"))?
                        };
                        let unfused = {
                            let _g = scoped_fused(false);
                            model
                                .forward_batch(&ids, &segs, backend, &mut scratch)
                                .map_err(|e| format!("unfused forward_batch: {e}"))?
                        };
                        for (i, (f, u)) in fused.iter().zip(&unfused).enumerate() {
                            if f.logits_i32 != u.logits_i32
                                || f.predicted != u.predicted
                                || f.logits != u.logits
                            {
                                return Err(format!(
                                    "batch[{i}] fused diverged from forced-unfused under {} \
                                     on {:?} (batch size {}, valid_len {}): {:?} vs {:?}",
                                    backend.name(),
                                    leg,
                                    batch.len(),
                                    batch[i].valid_len,
                                    f.logits_i32,
                                    u.logits_i32
                                ));
                            }
                        }
                        // The single-example entry point routes through
                        // the same fused forward; pin it on one example.
                        let ex = &batch[0];
                        let fused_one = {
                            let _g = scoped_fused(true);
                            model
                                .forward(&ex.ids, &ex.segments, backend, &mut scratch)
                                .map_err(|e| format!("fused forward: {e}"))?
                        };
                        let unfused_one = {
                            let _g = scoped_fused(false);
                            model
                                .forward(&ex.ids, &ex.segments, backend, &mut scratch)
                                .map_err(|e| format!("unfused forward: {e}"))?
                        };
                        if fused_one.logits_i32 != unfused_one.logits_i32 {
                            return Err(format!(
                                "single forward fused diverged from forced-unfused under {} \
                                 on {leg:?}",
                                backend.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
    drop(lock);
}

/// The decode contract re-run under the fused epilogue path: a decode
/// loop over `t = 1..=n` cached-K/V steps produces bit-identical
/// per-step logits to the full causal prefill at length `n` — and both
/// equal the forced-unfused prefill — in all four HCCS modes.  The
/// decoder hot loop routes its projections through the fused epilogues,
/// so this pins step-vs-prefill *and* fused-vs-unfused at once.
#[test]
fn prop_decoder_step_matches_prefill_under_fused_epilogues() {
    let task = TaskKind::Sst2s;
    let cfg = ModelConfig {
        layers: 2,
        heads: 2,
        d_model: 32,
        d_ff: 64,
        seq_len: task.max_len(),
        vocab: hccs::data::VOCAB_SIZE as usize,
        n_classes: 2,
    };
    let dec = NativeDecoder::new(cfg, task, 29).expect("decoder build");
    let nc = dec.cfg.vocab;
    let lock = FUSED_TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    check(
        "decoder-step-vs-prefill-fused",
        Config { cases: 4, ..Default::default() },
        |rng| rng.below(u64::MAX),
        |_| vec![],
        |&input_seed| {
            let mut generator = WorkloadGen::new(task, input_seed);
            let ex = std::iter::repeat_with(|| generator.next_example())
                .find(|ex| ex.valid_len >= 4)
                .expect("generator yields a usable prompt");
            let ids = &ex.ids[..ex.valid_len];
            let n = ids.len();
            let mut s = DecoderScratch::default();
            for backend in SoftmaxBackend::hccs_modes() {
                let unfused_full = {
                    let _g = scoped_fused(false);
                    let mut cache = dec.new_cache();
                    dec.prefill(ids, backend, &mut cache, &mut s)
                        .map_err(|e| format!("unfused prefill: {e}"))?
                };
                let _g = scoped_fused(true);
                let mut cache = dec.new_cache();
                let full = dec
                    .prefill(ids, backend, &mut cache, &mut s)
                    .map_err(|e| format!("fused prefill: {e}"))?;
                if full != unfused_full {
                    return Err(format!(
                        "fused prefill diverged from forced-unfused under {}",
                        backend.name()
                    ));
                }
                let mut step_cache = dec.new_cache();
                for (t, &id) in ids.iter().enumerate() {
                    let row = dec
                        .step(id, backend, &mut step_cache, &mut s)
                        .map_err(|e| format!("step {t}: {e}"))?;
                    if row != full[t * nc..(t + 1) * nc] {
                        return Err(format!(
                            "fused step {} diverged from prefill row under {} (prompt len {n})",
                            t + 1,
                            backend.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
    drop(lock);
}

// ---------------------------------------------------------------------------
// Native encoder determinism
// ---------------------------------------------------------------------------

/// Two models built from the same seed are the same function: equal
/// calibration, equal integer logits on fresh inputs, for HCCS and f32
/// backends alike.
#[test]
fn prop_native_encoder_deterministic_per_seed() {
    check(
        "native-encoder-determinism",
        Config { cases: 3, ..Default::default() },
        |rng| (rng.below(1000), rng.below(u64::MAX)),
        |_| vec![],
        |&(model_seed, input_seed)| {
            let task = TaskKind::Sst2s;
            let cfg = ModelConfig {
                layers: 1,
                heads: 2,
                d_model: 32,
                d_ff: 64,
                seq_len: task.max_len(),
                vocab: hccs::data::VOCAB_SIZE as usize,
                n_classes: 2,
            };
            let a = NativeModel::new(cfg, task, model_seed)
                .map_err(|e| format!("model build failed: {e}"))?;
            let b = NativeModel::new(cfg, task, model_seed)
                .map_err(|e| format!("model rebuild failed: {e}"))?;
            let mut generator = WorkloadGen::new(task, input_seed);
            let mut sa = EncoderScratch::default();
            let mut sb = EncoderScratch::default();
            for _ in 0..3 {
                let ex = generator.next_example();
                for backend in [
                    SoftmaxBackend::F32Ref,
                    SoftmaxBackend::Hccs { out_path: OutputPath::I16, recip: Reciprocal::Div },
                    SoftmaxBackend::Hccs { out_path: OutputPath::I8, recip: Reciprocal::Clb },
                ] {
                    let ra = a
                        .forward(&ex.ids, &ex.segments, backend, &mut sa)
                        .map_err(|e| format!("forward a: {e}"))?;
                    let rb = b
                        .forward(&ex.ids, &ex.segments, backend, &mut sb)
                        .map_err(|e| format!("forward b: {e}"))?;
                    if ra.logits_i32 != rb.logits_i32 || ra.predicted != rb.predicted {
                        return Err(format!(
                            "same-seed forwards diverged under {backend:?}: {:?} vs {:?}",
                            ra.logits_i32, rb.logits_i32
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Batched kernel engine
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct TileCase {
    x: Vec<i8>,
    rows: usize,
    cols: usize,
    theta: HccsParams,
}

fn gen_tile(rng: &mut Xoshiro256) -> TileCase {
    // Widths cover single-column, sub-lane, ragged (non-multiple-of-8)
    // and wide rows; row counts cover the single-row edge case and the
    // ragged last tile a deadline flush produces.
    let cols = *[1usize, 2, 5, 8, 13, 32, 64, 100, 128]
        .get(rng.below(9) as usize)
        .unwrap();
    let rows = 1 + rng.below(33) as usize;
    let theta = feasible_theta(rng, cols);
    let x = (0..rows * cols).map(|_| rng.i8()).collect();
    TileCase { x, rows, cols, theta }
}

fn shrink_tile(c: &TileCase) -> Vec<TileCase> {
    let mut out = Vec::new();
    if c.rows > 1 {
        // Halve the row count (θ stays feasible: cols is unchanged).
        let rows = c.rows / 2;
        out.push(TileCase {
            x: c.x[..rows * c.cols].to_vec(),
            rows,
            cols: c.cols,
            theta: c.theta,
        });
    }
    let mut damped = c.clone();
    if damped.x.iter().any(|&v| v != 0) {
        for v in damped.x.iter_mut() {
            *v /= 2;
        }
        out.push(damped);
    }
    out
}

/// The batched engine must be bit-exact with the row-at-a-time kernel on
/// every tile shape, for all four OutputPath x Reciprocal modes —
/// including single-row tiles and ragged widths.  This is what keeps the
/// paper's golden vectors valid for both entry points.
#[test]
fn prop_batch_bit_exact_with_row_kernel() {
    check(
        "batch-vs-row-bit-exact",
        Config { cases: 300, ..Default::default() },
        gen_tile,
        shrink_tile,
        |case| {
            for (op, rc) in [
                (OutputPath::I16, Reciprocal::Div),
                (OutputPath::I16, Reciprocal::Clb),
                (OutputPath::I8, Reciprocal::Div),
                (OutputPath::I8, Reciprocal::Clb),
            ] {
                let got = hccs_batch(&case.x, case.rows, case.cols, &case.theta, op, rc);
                let mut want = vec![0i32; case.x.len()];
                for r in 0..case.rows {
                    hccs_row_into(
                        &case.x[r * case.cols..(r + 1) * case.cols],
                        &case.theta,
                        op,
                        rc,
                        &mut want[r * case.cols..(r + 1) * case.cols],
                    );
                }
                if got != want {
                    let bad = got
                        .iter()
                        .zip(&want)
                        .position(|(a, b)| a != b)
                        .unwrap_or(0);
                    return Err(format!(
                        "divergence under {op:?}/{rc:?} at flat index {bad} \
                         (row {}, col {}): batched {} != rowwise {}",
                        bad / case.cols,
                        bad % case.cols,
                        got[bad],
                        want[bad]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The masked engine equals the row kernel on each row's valid prefix
/// and writes exact zeros on the pad tail — for every tile shape, every
/// per-row length mix, and all four modes.
#[test]
fn prop_masked_batch_bit_exact_with_prefix_rows_and_zero_pads() {
    check(
        "masked-batch-vs-prefix-rows",
        Config { cases: 300, ..Default::default() },
        |rng| {
            let case = gen_tile(rng);
            let lens: Vec<usize> =
                (0..case.rows).map(|_| 1 + rng.below(case.cols as u64) as usize).collect();
            (case, lens)
        },
        |_| vec![],
        |(case, lens)| {
            for (op, rc) in [
                (OutputPath::I16, Reciprocal::Div),
                (OutputPath::I16, Reciprocal::Clb),
                (OutputPath::I8, Reciprocal::Div),
                (OutputPath::I8, Reciprocal::Clb),
            ] {
                let got =
                    hccs_batch_masked(&case.x, case.rows, case.cols, lens, &case.theta, op, rc);
                for (r, &len) in lens.iter().enumerate() {
                    let mut want = vec![0i32; len];
                    hccs_row_into(
                        &case.x[r * case.cols..r * case.cols + len],
                        &case.theta,
                        op,
                        rc,
                        &mut want,
                    );
                    if got[r * case.cols..r * case.cols + len] != want[..] {
                        return Err(format!(
                            "masked row {r} (len {len}) diverged from prefix row kernel \
                             under {op:?}/{rc:?}"
                        ));
                    }
                    if got[r * case.cols + len..(r + 1) * case.cols].iter().any(|&v| v != 0) {
                        return Err(format!(
                            "pad columns of row {r} not exactly zero under {op:?}/{rc:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Batcher invariants
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct BatchScript {
    max_batch: usize,
    /// (request id, offset_us since start, poll_after) events.
    events: Vec<(u64, u64, bool)>,
}

fn gen_script(rng: &mut Xoshiro256) -> BatchScript {
    let max_batch = 1 + rng.below(12) as usize;
    let n = 1 + rng.below(64);
    let mut t = 0u64;
    let events = (0..n)
        .map(|i| {
            t += rng.below(4000);
            (i, t, rng.below(3) == 0)
        })
        .collect();
    BatchScript { max_batch, events }
}

/// Conservation + FIFO + size-bound under arbitrary push/poll schedules.
#[test]
fn prop_batcher_conserves_and_orders() {
    check(
        "batcher-conservation",
        Config { cases: 300, ..Default::default() },
        gen_script,
        |s| {
            let mut out = Vec::new();
            if s.events.len() > 1 {
                out.push(BatchScript {
                    max_batch: s.max_batch,
                    events: s.events[..s.events.len() / 2].to_vec(),
                });
            }
            if s.max_batch > 1 {
                out.push(BatchScript { max_batch: s.max_batch / 2 + 1, events: s.events.clone() });
            }
            out
        },
        |script| {
            let policy = BatchPolicy {
                max_batch: script.max_batch,
                max_wait: Duration::from_micros(2000),
            };
            let mut b = DynamicBatcher::new(policy);
            let t0 = Instant::now();
            let mut flushed: Vec<u64> = Vec::new();
            let mut collect = |batch: hccs::coordinator::Batch<u64>| {
                if batch.items.len() > script.max_batch {
                    return Err(format!(
                        "batch of {} > max {}",
                        batch.items.len(),
                        script.max_batch
                    ));
                }
                if batch.items.is_empty() {
                    return Err("empty batch".into());
                }
                flushed.extend(batch.items.iter().map(|q| q.payload));
                Ok(())
            };
            for &(id, off, poll) in &script.events {
                let now = t0 + Duration::from_micros(off);
                if let Some(batch) = b.push(id, now) {
                    collect(batch)?;
                }
                if poll {
                    if let Some(batch) = b.poll(now + Duration::from_micros(100)) {
                        collect(batch)?;
                    }
                }
            }
            for batch in b.drain() {
                collect(batch)?;
            }
            // Conservation: every id exactly once, FIFO order.
            let want: Vec<u64> = script.events.iter().map(|e| e.0).collect();
            if flushed != want {
                return Err(format!("order/conservation violated: {flushed:?} != {want:?}"));
            }
            if !b.is_empty() {
                return Err("requests left in queue after drain".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Streaming JSON framer (the TCP wire protocol)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct FrameStream {
    bytes: Vec<u8>,
    /// Chunk-size schedules to replay the stream under (cycled).
    schedules: Vec<Vec<usize>>,
}

fn gen_frame_stream(rng: &mut Xoshiro256) -> FrameStream {
    let n_frames = 1 + rng.below(6) as usize;
    let mut bytes = Vec::new();
    for k in 0..n_frames {
        // Inter-frame whitespace, sometimes none.
        for _ in 0..rng.below(3) {
            bytes.push(*[b' ', b'\n', b'\t', b'\r'].get(rng.below(4) as usize).unwrap());
        }
        match rng.below(4) {
            0 => bytes.extend_from_slice(
                format!("{{\"id\": {k}, \"text\": \"w{:03} good\"}}", k % 40).as_bytes(),
            ),
            // Escapes that hide structural bytes inside strings.
            1 => bytes.extend_from_slice(br#"{"text": "esc \" brace \\ } inside"}"#),
            // Nesting: braces/brackets the depth tracker must balance.
            2 => bytes.extend_from_slice(
                br#"{"meta": {"a": [1, 2, {"b": "}"}]}, "text": "nested"}"#,
            ),
            _ => bytes.extend_from_slice(
                format!("{{\"text\": \"{}\"}}", "x".repeat(1 + rng.below(40) as usize)).as_bytes(),
            ),
        }
    }
    bytes.push(b'\n');
    // The 1-byte-read worst case, plus random small-read schedules.
    let mut schedules = vec![vec![1]];
    for _ in 0..3 {
        schedules.push((0..1 + rng.below(8)).map(|_| 1 + rng.below(13) as usize).collect());
    }
    FrameStream { bytes, schedules }
}

/// The emitted frame sequence is invariant under re-chunking (1-byte
/// reads included), and the framer never buffers past `max_payload` —
/// the bounded-memory-by-construction contract of the TCP tier.
#[test]
fn prop_streaming_framer_chunking_invariant() {
    check(
        "framer-chunking-invariance",
        Config { cases: 300, ..Default::default() },
        gen_frame_stream,
        |_| vec![],
        |case| {
            let limits = FrameLimits::default();
            let mut reference = StreamingFramer::new(limits);
            let want = reference
                .push(&case.bytes)
                .map_err(|e| format!("reference push failed: {e}"))?;
            if reference.buffered() != 0 {
                return Err("reference left bytes buffered on a frame boundary".into());
            }
            for sched in &case.schedules {
                let mut f = StreamingFramer::new(limits);
                let mut got: Vec<Vec<u8>> = Vec::new();
                let (mut i, mut s) = (0usize, 0usize);
                while i < case.bytes.len() {
                    let n = sched[s % sched.len()].min(case.bytes.len() - i);
                    s += 1;
                    got.extend(
                        f.push(&case.bytes[i..i + n])
                            .map_err(|e| format!("chunked push failed: {e}"))?,
                    );
                    if f.buffered() > limits.max_payload {
                        return Err(format!("buffered {} > max_payload", f.buffered()));
                    }
                    i += n;
                }
                if got != want {
                    return Err(format!(
                        "frames differ under schedule {sched:?}: {} vs {} frames",
                        got.len(),
                        want.len()
                    ));
                }
                if !f.is_idle() {
                    return Err("framer not idle after a boundary-complete stream".into());
                }
            }
            Ok(())
        },
    );
}

#[derive(Clone, Debug)]
struct AdversarialStream {
    bytes: Vec<u8>,
    chunk: usize,
    must_error: bool,
}

fn gen_adversarial(rng: &mut Xoshiro256) -> AdversarialStream {
    let (bytes, must_error) = match rng.below(4) {
        // A string that never closes: must die at max_string, not grow.
        0 => {
            let mut b = b"{\"s\": \"".to_vec();
            b.resize(b.len() + 4096, b'a');
            (b, true)
        }
        // Pathological nesting: must die at max_depth.
        1 => {
            let mut b = b"{\"d\": ".to_vec();
            b.extend(vec![b'['; 256]);
            (b, true)
        }
        // Garbage between frames: a desynchronized stream must poison
        // the connection, never resync onto the trailing frame.
        2 => {
            let mut b = br#"{"text": "ok"}"#.to_vec();
            b.extend_from_slice(b" SYN/ACK <<garbage>> ");
            b.extend_from_slice(br#"{"text": "late"}"#);
            (b, true)
        }
        // Uniform random bytes (may happen to be almost-valid).
        _ => ((0..2048).map(|_| rng.below(256) as u8).collect(), false),
    };
    AdversarialStream { bytes, chunk: 1 + rng.below(64) as usize, must_error }
}

/// Adversarial input produces a *connection error*, never a panic or
/// unbounded buffering — and a poisoned framer stays poisoned (no
/// silent resynchronization after garbage).
#[test]
fn prop_streaming_framer_bounded_memory_under_attack() {
    check(
        "framer-adversarial-bounded",
        Config { cases: 300, ..Default::default() },
        gen_adversarial,
        |_| vec![],
        |case| {
            let limits = FrameLimits { max_payload: 128, max_depth: 8, max_string: 32 };
            let mut f = StreamingFramer::new(limits);
            let mut errored = false;
            for c in case.bytes.chunks(case.chunk) {
                match f.push(c) {
                    Ok(_) if errored => {
                        return Err("push succeeded after the framer was poisoned".into())
                    }
                    Ok(_) => {}
                    Err(_) => errored = true,
                }
                if f.buffered() > limits.max_payload {
                    return Err(format!(
                        "buffered {} > max_payload {} mid-attack",
                        f.buffered(),
                        limits.max_payload
                    ));
                }
            }
            if case.must_error && !errored {
                return Err("adversarial stream was accepted without a connection error".into());
            }
            Ok(())
        },
    );
}

/// Deadline guarantee: once `poll` is called at/after head+max_wait, the
/// head request must flush.
#[test]
fn prop_batcher_deadline() {
    check(
        "batcher-deadline",
        Config { cases: 200, ..Default::default() },
        |rng| (1 + rng.below(7) as usize, rng.below(10_000)),
        |&(mb, w)| {
            shrink_int(w as i64)
                .into_iter()
                .filter(|&v| v >= 0)
                .map(|v| (mb, v as u64))
                .collect()
        },
        |&(max_batch, wait_us)| {
            let policy =
                BatchPolicy { max_batch, max_wait: Duration::from_micros(wait_us) };
            let mut b = DynamicBatcher::new(policy);
            let t0 = Instant::now();
            if b.push(7u64, t0).is_some() {
                // max_batch == 1: size flush is immediate; fine.
                return Ok(());
            }
            let at_deadline = t0 + Duration::from_micros(wait_us);
            match b.poll(at_deadline) {
                Some(batch) if batch.items[0].payload == 7 => Ok(()),
                Some(_) => Err("wrong request flushed".into()),
                None => Err(format!("deadline {wait_us}us not honored")),
            }
        },
    );
}
