//! Wires the repo-native static analyzer (`tools/analyze.py`) into
//! `cargo test`: the tree must lint clean, every seeded fixture must
//! fire, and the analyzer's own unit tests must pass.
//!
//! The analyzer is stdlib-only Python. When no Python interpreter is
//! on `PATH` (minimal build images), these tests skip loudly rather
//! than fail — CI runs the analyzer as its own blocking job either way.

use std::path::{Path, PathBuf};
use std::process::Command;

fn repo_root() -> PathBuf {
    // CARGO_MANIFEST_DIR is rust/; the analyzer lives one level up.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent directory")
        .to_path_buf()
}

/// First working Python 3 interpreter on PATH, if any.
fn python() -> Option<&'static str> {
    for cand in ["python3", "python"] {
        let probe = Command::new(cand)
            .arg("-c")
            .arg("import sys; sys.exit(0 if sys.version_info[0] >= 3 else 1)")
            .status();
        if matches!(probe, Ok(s) if s.success()) {
            return Some(cand);
        }
    }
    None
}

fn run_tool(args: &[&str]) {
    let Some(py) = python() else {
        eprintln!("skipping: no python3/python on PATH (analyzer runs as its own CI job)");
        return;
    };
    let root = repo_root();
    let out = Command::new(py)
        .args(args)
        .arg("--root")
        .arg(&root)
        .current_dir(&root)
        .output()
        .expect("spawn python analyzer");
    assert!(
        out.status.success(),
        "`{py} {}` failed\n--- stdout ---\n{}\n--- stderr ---\n{}",
        args.join(" "),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
#[cfg_attr(miri, ignore = "spawns a Python subprocess")]
fn tree_lints_clean() {
    run_tool(&["tools/analyze.py"]);
}

#[test]
#[cfg_attr(miri, ignore = "spawns a Python subprocess")]
fn every_seeded_fixture_fires() {
    run_tool(&["tools/analyze.py", "--fixtures"]);
}

#[test]
#[cfg_attr(miri, ignore = "spawns a Python subprocess")]
fn analyzer_unit_tests_pass() {
    let Some(py) = python() else {
        eprintln!("skipping: no python3/python on PATH (analyzer runs as its own CI job)");
        return;
    };
    let root = repo_root();
    let out = Command::new(py)
        .arg("tools/test_analyze.py")
        .current_dir(&root)
        .output()
        .expect("spawn analyzer unit tests");
    assert!(
        out.status.success(),
        "`{py} tools/test_analyze.py` failed\n--- stdout ---\n{}\n--- stderr ---\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}
