//! Integration tests for decode sessions on the sharded native
//! serving substrate (`NativeBackend::with_decoder`): mixed
//! decode + classification traffic through the same executors,
//! per-shard FIFO reply integrity, session load accounting, and the
//! load-bearing shed contract — a deadline-shed decode step fast-fails
//! **without touching the session's K/V state**, so a retry streams
//! exactly the tokens an unshed twin would.
//!
//! Every test body runs under [`with_timeout`] so a wedged executor or
//! a starved queue fails the suite instead of hanging CI.  The file is
//! dispatch-agnostic and runs on both `HCCS_FORCE_SCALAR` legs.

use std::sync::mpsc;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use hccs::coordinator::{is_shed_error, BatchPolicy};
use hccs::data::{TaskKind, WorkloadGen};
use hccs::model::{
    DecoderScratch, EncoderScratch, ModelConfig, NativeBackend, NativeDecoder, NativeModel,
    NativeServeConfig, SoftmaxBackend,
};
use hccs::server::InferBackend;

/// Fail loudly instead of hanging (same pattern as tcp_serving.rs).
fn with_timeout<F: FnOnce() + Send + 'static>(secs: u64, f: F) {
    let (tx, rx) = mpsc::channel();
    let body = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(panic) = body.join() {
                std::panic::resume_unwind(panic);
            }
        }
        Err(mpsc::RecvTimeoutError::Timeout) => panic!("test timed out after {secs}s"),
    }
}

fn tiny_cfg() -> ModelConfig {
    let task = TaskKind::Sst2s;
    ModelConfig {
        layers: 1,
        heads: 2,
        d_model: 32,
        d_ff: 64,
        seq_len: task.max_len(),
        vocab: hccs::data::VOCAB_SIZE as usize,
        n_classes: 2,
    }
}

/// Calibrated once per test binary (the expensive part).
fn native_model() -> Arc<NativeModel> {
    static MODEL: OnceLock<Arc<NativeModel>> = OnceLock::new();
    MODEL
        .get_or_init(|| Arc::new(NativeModel::new(tiny_cfg(), TaskKind::Sst2s, 42).unwrap()))
        .clone()
}

fn native_decoder() -> Arc<NativeDecoder> {
    static DEC: OnceLock<Arc<NativeDecoder>> = OnceLock::new();
    DEC.get_or_init(|| Arc::new(NativeDecoder::new(tiny_cfg(), TaskKind::Sst2s, 5).unwrap()))
        .clone()
}

fn mode() -> SoftmaxBackend {
    SoftmaxBackend::parse("i16_div").unwrap()
}

fn decode_backend(shards: usize) -> Arc<NativeBackend> {
    Arc::new(
        NativeBackend::with_decoder(
            native_model(),
            native_decoder(),
            mode(),
            NativeServeConfig {
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                shards,
                length_bands: 2,
                max_in_flight: None,
            },
        )
        .unwrap(),
    )
}

/// Valid-prefix prompts from real workload examples.
fn prompts(n: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut generator = WorkloadGen::new(TaskKind::Sst2s, seed);
    (0..n)
        .map(|_| {
            let ex = generator.next_example();
            ex.ids[..ex.valid_len].to_vec()
        })
        .collect()
}

/// Drive one open session to completion through the serving path,
/// collecting up to `budget` greedy tokens.
fn run_session(backend: &NativeBackend, prompt: Vec<i32>, budget: usize) -> Vec<i32> {
    let (handle, first) = backend.open_session(prompt, None).unwrap();
    let r = first.recv().unwrap().unwrap();
    let mut got = vec![r.token];
    let mut done = r.done;
    while !done && got.len() < budget {
        let r = backend.step_session(&handle, None).unwrap().recv().unwrap().unwrap();
        got.push(r.token);
        done = r.done;
    }
    got
}

/// Mixed traffic: concurrent decode sessions and classification
/// requests share the same shards, and every client sees exactly the
/// replies the single-threaded reference paths produce — decode
/// sessions stream the direct greedy tokens, classifications return
/// the direct forward predictions, and nobody starves (the watchdog is
/// the starvation bound).
#[test]
fn mixed_decode_and_classification_traffic_serves_both_correctly() {
    with_timeout(120, || {
        const BUDGET: usize = 5;
        let backend = decode_backend(2);
        let dec = native_decoder();
        let model = native_model();

        // Single-threaded references, computed before any load exists.
        let mut generator = WorkloadGen::new(TaskKind::Sst2s, 11);
        let examples: Vec<_> = (0..12).map(|_| generator.next_example()).collect();
        let mut enc_scratch = EncoderScratch::default();
        let expected_cls: Vec<i32> = examples
            .iter()
            .map(|ex| {
                model.forward(&ex.ids, &ex.segments, mode(), &mut enc_scratch).unwrap().predicted
            })
            .collect();
        let session_prompts = prompts(4, 23);
        let mut dec_scratch = DecoderScratch::default();
        let expected_tokens: Vec<Vec<i32>> = session_prompts
            .iter()
            .map(|p| dec.generate(p, BUDGET, mode(), &mut dec_scratch).unwrap().tokens)
            .collect();

        // Concurrent clients: one thread per decode session, one
        // classification thread hammering all examples twice.
        let mut clients = Vec::new();
        for (prompt, want) in session_prompts.iter().zip(&expected_tokens) {
            let (backend, prompt, want) = (backend.clone(), prompt.clone(), want.clone());
            clients.push(std::thread::spawn(move || {
                let got = run_session(&backend, prompt, BUDGET);
                assert_eq!(got, want, "served session must stream the direct greedy decode");
            }));
        }
        {
            let (backend, examples, expected_cls) =
                (backend.clone(), examples.clone(), expected_cls.clone());
            clients.push(std::thread::spawn(move || {
                for round in 0..2 {
                    for (ex, want) in examples.iter().zip(&expected_cls) {
                        let rx = backend
                            .submit_request(ex.ids.clone(), ex.segments.clone())
                            .unwrap();
                        let reply = rx.recv().unwrap().unwrap();
                        assert_eq!(
                            reply.predicted, *want,
                            "round {round}: classification under decode load must match \
                             the direct forward"
                        );
                    }
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        backend.shutdown();
    });
}

/// The no-poisoning contract: a decode step whose deadline expired in
/// the queue is shed *before* the executor touches session state, so
/// the session's `next` cursor and K/V ring are exactly as if the step
/// was never requested — the retry produces the same tokens as an
/// unshed twin session on the same prompt.
#[test]
fn shed_decode_step_fast_fails_without_poisoning_the_session() {
    with_timeout(120, || {
        const BUDGET: usize = 4;
        let backend = decode_backend(1);
        let prompt = prompts(1, 31).remove(0);

        // Twin session, never shed: the reference token stream.
        let want = run_session(&backend, prompt.clone(), BUDGET);

        let (handle, first) = backend.open_session(prompt, None).unwrap();
        let r = first.recv().unwrap().unwrap();
        let mut got = vec![r.token];
        let mut done = r.done;

        // A step whose deadline expires while it queues must fast-fail
        // with a shed reply.  The budget is far below the 1ms batch
        // wait, so the op is alive at admission and dead at the
        // executor's pre-batch sweep — the sweep runs *before* any
        // session state is touched (that ordering is the contract under
        // test).  On a slow machine admission itself may catch it;
        // both paths are a shed, neither consumes the step.
        let near = Instant::now() + Duration::from_micros(200);
        let err = match backend.step_session(&handle, Some(near)) {
            Err(e) => format!("{e:#}"),
            Ok(rx) => rx.recv().unwrap().expect_err("expired step must shed"),
        };
        assert!(is_shed_error(&err), "expected a shed reply, got: {err}");

        // ...and the session is not poisoned: the retry (and every
        // later step) streams exactly the twin's remaining tokens.
        while !done && got.len() < BUDGET {
            let r = backend.step_session(&handle, None).unwrap().recv().unwrap().unwrap();
            got.push(r.token);
            done = r.done;
        }
        assert_eq!(
            got, want,
            "a shed step must leave the K/V ring and token cursor untouched"
        );
        backend.shutdown();
    });
}

/// Open sessions pin a router ticket, so a shard with long-lived
/// sessions reports them as outstanding load until the handles drop
/// (the RAII close path the TCP tier relies on for dead connections).
#[test]
fn open_sessions_count_as_shard_load_until_their_handles_drop() {
    with_timeout(120, || {
        let backend = decode_backend(2);
        let outstanding =
            |b: &NativeBackend| (0..b.shards()).map(|s| b.outstanding(s)).sum::<u64>();

        let mut sessions = Vec::new();
        for prompt in prompts(3, 47) {
            let (handle, first) = backend.open_session(prompt, None).unwrap();
            first.recv().unwrap().unwrap();
            sessions.push(handle);
        }
        assert_eq!(
            outstanding(&backend),
            3,
            "each open session must hold one routed-load ticket"
        );

        drop(sessions);
        // Close ops are processed asynchronously by the executors.
        let deadline = Instant::now() + Duration::from_secs(30);
        while outstanding(&backend) != 0 {
            assert!(
                Instant::now() < deadline,
                "session tickets leaked: {} still outstanding",
                outstanding(&backend)
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        backend.shutdown();
    });
}
