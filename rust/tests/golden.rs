//! Cross-language golden-vector tests: the Rust HCCS core must agree
//! *bit-for-bit* with the numpy oracle (and hence the Pallas kernel) on
//! the shared vectors in `artifacts/golden/hccs_rows.json`.
//!
//! Skips (with a loud message) when artifacts have not been built yet;
//! `make artifacts && cargo test` exercises the full chain.

use std::path::PathBuf;

use hccs::hccs::{hccs_row, HccsParams, OutputPath, Reciprocal};
use hccs::json::Value;

fn artifacts_dir() -> PathBuf {
    // Tests run from the workspace member dir or the root; try both.
    for base in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(base);
        if p.exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

fn load_golden() -> Option<Value> {
    let path = artifacts_dir().join("golden/hccs_rows.json");
    if !path.exists() {
        eprintln!("SKIP golden tests: {} missing (run `make artifacts`)", path.display());
        return None;
    }
    Some(Value::parse(&std::fs::read_to_string(path).unwrap()).unwrap())
}

fn mode_of(name: &str) -> (OutputPath, Reciprocal) {
    hccs::hccs::kernel::parse_mode(name).unwrap()
}

#[test]
fn rust_core_matches_python_oracle_bit_exactly() {
    let Some(golden) = load_golden() else { return };
    let cases = golden.req("cases").as_arr().unwrap();
    assert!(cases.len() >= 20, "suspiciously few golden cases");
    let mut checked = 0;
    for case in cases {
        let n = case.req("n").as_i64().unwrap() as usize;
        let x: Vec<i8> = case.req("x").flat_f64().iter().map(|&v| v as i8).collect();
        assert_eq!(x.len(), n);
        let p = HccsParams::checked(
            case.req("B").as_i64().unwrap() as i32,
            case.req("S").as_i64().unwrap() as i32,
            case.req("Dmax").as_i64().unwrap() as i32,
            n,
        )
        .expect("golden params must be feasible");
        if let Value::Obj(outs) = case.req("out") {
            for (mode, want_v) in outs {
                let (op, rc) = mode_of(mode);
                let want: Vec<i32> = want_v.flat_f64().iter().map(|&v| v as i32).collect();
                let got = hccs_row(&x, &p, op, rc);
                assert_eq!(got, want, "mismatch: n={n} mode={mode} theta={p:?}");
                checked += 1;
            }
        }
    }
    assert!(checked >= 80, "only {checked} vectors checked");
}

/// The exported Pallas-kernel HLO artifact, executed through PJRT, must
/// also match the Rust core — this closes the loop across all three
/// implementations (numpy oracle ≡ Pallas/XLA ≡ Rust).
#[test]
fn kernel_hlo_artifact_matches_rust_core() {
    let dir = artifacts_dir();
    let path = dir.join("hccs_softmax_i16_div_n64.hlo.txt");
    if !path.exists() {
        eprintln!("SKIP kernel artifact test: {} missing", path.display());
        return;
    }
    let rt = std::rc::Rc::new(hccs::runtime::Runtime::cpu().unwrap());
    let runner = hccs::runtime::KernelRunner::load(rt, &path, 8, 64).unwrap();

    let mut rng = hccs::rng::Xoshiro256::new(2024);
    let rows = 8;
    let n = 64;
    let x: Vec<i8> = (0..rows * n).map(|_| rng.i8()).collect();
    let p = HccsParams::checked(300, 4, 64, n).unwrap();
    let b = vec![p.b; rows];
    let s = vec![p.s; rows];
    let d = vec![p.dmax; rows];
    let got = runner.run(&x, &b, &s, &d).unwrap();

    for r in 0..rows {
        let want = hccs_row(&x[r * n..(r + 1) * n], &p, OutputPath::I16, Reciprocal::Div);
        assert_eq!(&got[r * n..(r + 1) * n], &want[..], "row {r} differs (PJRT vs rust)");
    }
}

#[test]
fn i8_clb_kernel_artifact_matches_rust_core() {
    let dir = artifacts_dir();
    let path = dir.join("hccs_softmax_i8_clb_n128.hlo.txt");
    if !path.exists() {
        eprintln!("SKIP kernel artifact test: {} missing", path.display());
        return;
    }
    let rt = std::rc::Rc::new(hccs::runtime::Runtime::cpu().unwrap());
    let runner = hccs::runtime::KernelRunner::load(rt, &path, 8, 128).unwrap();
    let mut rng = hccs::rng::Xoshiro256::new(7);
    let (rows, n) = (8usize, 128usize);
    let x: Vec<i8> = (0..rows * n).map(|_| rng.i8()).collect();
    // Per-row varying θ exercises the parameter plumbing.
    let thetas: Vec<HccsParams> = (0..rows)
        .map(|i| {
            let s = 1 + (i as i32 % 2);
            let dmax = 32 + 8 * i as i32;
            let (lo, hi) = HccsParams::feasible_b_band(s, dmax, n).unwrap();
            HccsParams::checked((lo + hi) / 2, s, dmax, n).unwrap()
        })
        .collect();
    let b: Vec<i32> = thetas.iter().map(|p| p.b).collect();
    let s: Vec<i32> = thetas.iter().map(|p| p.s).collect();
    let d: Vec<i32> = thetas.iter().map(|p| p.dmax).collect();
    let got = runner.run(&x, &b, &s, &d).unwrap();
    for (r, p) in thetas.iter().enumerate() {
        let want = hccs_row(&x[r * n..(r + 1) * n], p, OutputPath::I8, Reciprocal::Clb);
        assert_eq!(&got[r * n..(r + 1) * n], &want[..], "row {r}");
    }
}

/// Dataset artifacts must decode and the Rust workload generator must
/// reproduce them exactly (same splitmix64 stream ⇒ same examples).
#[test]
fn eval_datasets_match_rust_generator() {
    let dir = artifacts_dir();
    for (task, file) in [
        (hccs::data::TaskKind::Sst2s, "eval_sst2s.bin"),
        (hccs::data::TaskKind::Mnlis, "eval_mnlis.bin"),
    ] {
        let path = dir.join(file);
        if !path.exists() {
            eprintln!("SKIP dataset cross-check: {} missing", path.display());
            continue;
        }
        let ds = hccs::data::Dataset::load(&path).unwrap();
        assert_eq!(ds.len(), 512);
        assert_eq!(ds.seq_len, task.max_len());
        assert_eq!(ds.n_classes, task.n_classes());
        // Python used make_dataset(task, 512, seed=2).
        let mut generator = hccs::data::WorkloadGen::new(task, 2);
        for (i, e) in ds.examples.iter().enumerate() {
            let g = generator.next_example();
            assert_eq!(g.ids, e.ids, "{file} example {i}: ids differ");
            assert_eq!(g.segments, e.segments, "{file} example {i}: segments differ");
            assert_eq!(g.label, e.label, "{file} example {i}: label differs");
        }
    }
}

/// Calibration artifacts load, validate, and pass the feasibility region.
#[test]
fn calibration_artifacts_are_feasible() {
    let dir = artifacts_dir();
    let mut found = 0;
    for (model, task, n) in [
        ("bert-tiny", "sst2s", 64),
        ("bert-tiny", "mnlis", 128),
        ("bert-small", "sst2s", 64),
        ("bert-small", "mnlis", 128),
    ] {
        for suffix in ["", "_fast"] {
            let p = dir.join(format!("calib_{model}_{task}{suffix}.json"));
            if p.exists() {
                let store = hccs::coordinator::HeadParamStore::load(&p, n).unwrap();
                assert!(store.per_head.layers >= 2);
                assert!(store.per_head.kl.iter().all(|&k| k.is_finite() && k >= 0.0));
                found += 1;
                break;
            }
        }
    }
    if found == 0 {
        eprintln!("SKIP calibration artifact test: no calib_*.json yet");
    }
}
