//! End-to-end integration: coordinator + PJRT + artifacts, plus failure
//! injection on the load path.  Artifact-dependent cases skip loudly when
//! `make artifacts` has not run.

use std::path::{Path, PathBuf};
use std::time::Duration;

use hccs::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use hccs::data::{TaskKind, WorkloadGen};
use hccs::server;
use hccs::tokenizer::Tokenizer;

fn artifacts_dir() -> Option<PathBuf> {
    for base in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(base);
        if p.join("vocab.json").exists() {
            return Some(p);
        }
    }
    None
}

fn tiny_ready(artifacts: &Path) -> bool {
    hccs::runtime::manifest::summary_path(artifacts, "bert-tiny", "sst2s").is_some()
}

#[test]
fn coordinator_serves_batches_and_preserves_request_identity() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("SKIP e2e: no artifacts");
        return;
    };
    if !tiny_ready(&artifacts) {
        eprintln!("SKIP e2e: bert-tiny/sst2s summary not built yet");
        return;
    }
    let (coord, handle) = Coordinator::start(CoordinatorConfig {
        artifacts,
        model: "bert-tiny".into(),
        task: "sst2s".into(),
        variant: "hccs".into(),
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        max_in_flight: None,
        shards: 1,
    })
    .expect("start coordinator");

    // 40 requests: includes a partial final batch (deadline flush).
    let mut generator = WorkloadGen::new(TaskKind::Sst2s, 5);
    let examples: Vec<_> = (0..40).map(|_| generator.next_example()).collect();
    let rxs: Vec<_> = examples
        .iter()
        .map(|e| coord.submit(e.ids.clone(), e.segments.clone()).unwrap())
        .collect();
    let mut correct = 0;
    for (rx, e) in rxs.into_iter().zip(&examples) {
        let reply = rx.recv().unwrap().expect("inference ok");
        assert!(reply.predicted < 2);
        assert_eq!(reply.logits.len(), 2);
        assert!(reply.logits.iter().all(|v| v.is_finite()));
        correct += (reply.predicted as i32 == e.label) as usize;
    }
    // The QAT model must be far above chance on its own task.
    assert!(correct >= 24, "only {correct}/40 correct — model not serving properly");

    // Submitting identical inputs twice must give identical outputs
    // (determinism through the whole batching + PJRT stack).
    let e = &examples[0];
    let a = coord.infer(e.ids.clone(), e.segments.clone()).unwrap();
    let b = coord.infer(e.ids.clone(), e.segments.clone()).unwrap();
    assert_eq!(a.predicted, b.predicted);
    assert_eq!(a.logits, b.logits);

    coord.shutdown();
    handle.join().unwrap();
    assert!(coord.metrics.counter("coordinator.requests").get() >= 42);
    assert!(coord.metrics.counter("coordinator.batches").get() >= 6);
}

#[test]
fn text_server_round_trip() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("SKIP server test: no artifacts");
        return;
    };
    if !tiny_ready(&artifacts) {
        eprintln!("SKIP server test: summary not built yet");
        return;
    }
    let tokenizer = Tokenizer::load(&artifacts.join("vocab.json")).unwrap();
    let (coord, handle) = Coordinator::start(CoordinatorConfig {
        artifacts,
        model: "bert-tiny".into(),
        task: "sst2s".into(),
        variant: "hccs".into(),
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        max_in_flight: None,
        shards: 1,
    })
    .unwrap();
    let input = "good01 good02 w003\nnot good01 bad04 bad05\n# comment\n\n";
    let mut out = Vec::new();
    let n = server::serve(
        &coord,
        &tokenizer,
        TaskKind::Sst2s,
        std::io::BufReader::new(input.as_bytes()),
        &mut out,
    )
    .unwrap();
    assert_eq!(n, 2);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2);
    for line in &lines {
        let mut parts = line.split_whitespace();
        let pred: usize = parts.next().unwrap().parse().unwrap();
        assert!(pred < 2);
        let probs: Vec<f32> = parts.map(|p| p.parse().unwrap()).collect();
        assert_eq!(probs.len(), 2);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-3);
    }
    coord.shutdown();
    handle.join().unwrap();
}

// ---------------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------------

#[test]
fn missing_artifacts_fail_loudly_not_silently() {
    let err = Coordinator::start(CoordinatorConfig {
        artifacts: PathBuf::from("/nonexistent"),
        model: "bert-tiny".into(),
        task: "sst2s".into(),
        variant: "hccs".into(),
        policy: BatchPolicy::default(),
        max_in_flight: None,
        shards: 1,
    })
    .err()
    .expect("must not start without artifacts");
    let msg = format!("{err:#}");
    assert!(msg.contains("bert-tiny"), "unhelpful error: {msg}");
}

#[test]
fn corrupt_weights_rejected_at_load() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("SKIP corrupt-weights test: no artifacts");
        return;
    };
    let Some(spath) = hccs::runtime::manifest::summary_path(&artifacts, "bert-tiny", "sst2s")
    else {
        eprintln!("SKIP corrupt-weights test: summary not built yet");
        return;
    };
    // Copy artifacts view into a temp dir with truncated weights.
    let tmp = std::env::temp_dir().join(format!("hccs_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let summary = hccs::runtime::PairSummary::load(&spath).unwrap();
    let mani = summary.manifest("hccs", 8).unwrap();
    std::fs::copy(artifacts.join(&mani.hlo), tmp.join(&mani.hlo)).unwrap();
    let wbytes = std::fs::read(artifacts.join(&mani.weights)).unwrap();
    std::fs::write(tmp.join(&mani.weights), &wbytes[..wbytes.len() / 2]).unwrap();
    let rt = std::rc::Rc::new(hccs::runtime::Runtime::cpu().unwrap());
    let err = hccs::runtime::ModelRunner::load(rt, &tmp, mani.clone()).err();
    assert!(err.is_some(), "truncated weights must not load");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn wrong_shape_inputs_rejected_at_run() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("SKIP shape test: no artifacts");
        return;
    };
    let Some(spath) = hccs::runtime::manifest::summary_path(&artifacts, "bert-tiny", "sst2s")
    else {
        eprintln!("SKIP shape test: summary not built yet");
        return;
    };
    let summary = hccs::runtime::PairSummary::load(&spath).unwrap();
    let mani = summary.manifest("hccs", 1).unwrap().clone();
    let rt = std::rc::Rc::new(hccs::runtime::Runtime::cpu().unwrap());
    let runner = hccs::runtime::ModelRunner::load(rt, &artifacts, mani).unwrap();
    assert!(runner.run(&[1, 2, 3], &[0, 0, 0]).is_err(), "short input must error");
}
