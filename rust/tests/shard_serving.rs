//! Integration: `server::serve` end-to-end through a **multi-shard**
//! engine, with no PJRT artifacts required.
//!
//! An adapter implements [`server::InferBackend`] over the sharded
//! [`ScoreEngine`]: the first real token of each request selects the
//! hot logit position of a synthetic int8 row, so the reply's argmax
//! tags exactly which request it answers.  A deterministic per-request
//! jitter delays reply delivery by different amounts, scrambling
//! completion order across shards — the server must still emit one
//! response line per request **in input order**, while skipping
//! comment/empty lines and serving malformed (all-`[UNK]`) ones.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::time::Duration;

use hccs::coordinator::{BatchPolicy, EngineHandle, InferReply, ScoreConfig, ScoreEngine};
use hccs::data::TaskKind;
use hccs::error::Result;
use hccs::hccs::{HccsParams, OutputPath, Reciprocal};
use hccs::server::{self, InferBackend};
use hccs::tokenizer::Tokenizer;

const N: usize = 32;

fn tokenizer() -> Tokenizer {
    let mut toks: Vec<String> = ["[PAD]", "[CLS]", "[SEP]", "[UNK]"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    for i in 0..N {
        toks.push(format!("t{i:03}"));
    }
    Tokenizer::from_tokens(toks).unwrap()
}

fn start_engine(shards: usize) -> (ScoreEngine, EngineHandle) {
    ScoreEngine::start(ScoreConfig {
        n: N,
        params: HccsParams::checked(300, 4, 16, N).unwrap(),
        out_path: OutputPath::I16,
        recip: Reciprocal::Div,
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        max_in_flight: None,
        shards,
    })
    .unwrap()
}

/// Logit position lit up for a tokenized request: its first real token
/// id, shifted past the 4 specials ([UNK] requests land on position 0).
fn hot_position(ids: &[i32]) -> usize {
    (ids.get(1).copied().unwrap_or(0).max(0) as usize).saturating_sub(4) % N
}

/// Adapter: tokenized request → synthetic int8 row → sharded scoring.
struct ScoreFront {
    engine: ScoreEngine,
    seq: AtomicU64,
}

impl InferBackend for ScoreFront {
    fn submit_request(
        &self,
        ids: Vec<i32>,
        _segments: Vec<i32>,
    ) -> Result<Receiver<Result<InferReply, String>>> {
        let mut row = vec![-60i8; N];
        row[hot_position(&ids)] = 60;
        let score_rx = self.engine.submit(row)?;
        let k = self.seq.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        // Bridge thread: map the score reply into an InferReply, after a
        // per-request jitter that scrambles delivery order.
        std::thread::spawn(move || {
            let reply = score_rx.recv();
            std::thread::sleep(Duration::from_millis((k * 7) % 23));
            let mapped = match reply {
                Ok(Ok(r)) => {
                    let logits: Vec<f32> =
                        r.phat.iter().map(|&v| v as f32 / 32767.0).collect();
                    let predicted = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(j, _)| j)
                        .unwrap_or(0);
                    Ok(InferReply { id: k, predicted, logits, latency: r.latency })
                }
                Ok(Err(e)) => Err(e),
                Err(_) => Err("score engine dropped request".to_string()),
            };
            let _ = tx.send(mapped);
        });
        Ok(rx)
    }
}

/// The serve input: request lines interleaved with comments, blanks,
/// and malformed (unknown-token) lines.  Returns (input, expected hot
/// positions of the lines that must be served, in input order).
fn build_input(tok: &Tokenizer, requests: usize) -> (String, Vec<usize>) {
    let max_len = TaskKind::Sst2s.max_len();
    let mut input = String::from("# leading comment\n\n");
    let mut lines: Vec<String> = Vec::new();
    for k in 0..requests {
        lines.push(format!("t{:03}", (requests - 1 - k) % N));
        if k % 5 == 2 {
            lines.push("# interleaved comment".to_string());
        }
        if k % 7 == 3 {
            lines.push(String::new());
        }
        if k % 11 == 4 {
            lines.push("??? totally unknown $tokens".to_string());
        }
    }
    let mut expected = Vec::new();
    for line in &lines {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            input.push_str(line);
            input.push('\n');
            continue;
        }
        let (ids, _) = server::encode_request(tok, TaskKind::Sst2s, t, max_len);
        expected.push(hot_position(&ids));
        input.push_str(line);
        input.push('\n');
    }
    (input, expected)
}

fn serve_through(shards: usize, input: &str, tok: &Tokenizer) -> (u64, String, ScoreEngine) {
    let (engine, handle) = start_engine(shards);
    let front = ScoreFront { engine: engine.clone(), seq: AtomicU64::new(0) };
    let mut out = Vec::new();
    let served = server::serve(
        &front,
        tok,
        TaskKind::Sst2s,
        std::io::BufReader::new(input.as_bytes()),
        &mut out,
    )
    .unwrap();
    engine.shutdown();
    handle.join().unwrap();
    (served, String::from_utf8(out).unwrap(), engine)
}

#[test]
fn multi_shard_serve_preserves_input_order_under_scrambled_completion() {
    let tok = tokenizer();
    let (input, expected) = build_input(&tok, 48);
    let (served, text, engine) = serve_through(4, &input, &tok);
    assert_eq!(served as usize, expected.len(), "comment/blank lines must be skipped");

    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), expected.len());
    for (i, (line, want)) in lines.iter().zip(&expected).enumerate() {
        let mut parts = line.split_whitespace();
        let predicted: usize = parts.next().unwrap().parse().unwrap();
        assert_eq!(
            predicted, *want,
            "line {i}: reply order diverged from input order"
        );
        let probs: Vec<f32> = parts.map(|p| p.parse().unwrap()).collect();
        assert_eq!(probs.len(), N);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-2);
    }

    // The workload must actually have exercised every shard.
    let m = &engine.metrics;
    assert_eq!(m.counter("scorer.requests").get(), served);
    for shard in 0..4 {
        let per = m.counter(&format!("scorer.requests.shard{shard}")).get();
        assert!(per > 0, "shard {shard} never served a request");
    }
    assert_eq!(m.sum_counters("scorer.requests.shard"), served);
}

#[test]
fn multi_shard_serve_output_is_identical_to_single_shard() {
    let tok = tokenizer();
    let (input, _) = build_input(&tok, 40);
    let (served1, text1, _) = serve_through(1, &input, &tok);
    let (served4, text4, _) = serve_through(4, &input, &tok);
    assert_eq!(served1, served4);
    assert_eq!(text1, text4, "sharding must not change served bytes");
}
