//! Integration: `server::serve` end-to-end through a **multi-shard**
//! engine, with no PJRT artifacts required.
//!
//! An adapter implements [`server::InferBackend`] over the sharded
//! [`ScoreEngine`]: the first real token of each request selects the
//! hot logit position of a synthetic int8 row, so the reply's argmax
//! tags exactly which request it answers.  A deterministic per-request
//! jitter delays reply delivery by different amounts, scrambling
//! completion order across shards — the server must still emit one
//! response line per request **in input order**, while skipping
//! comment/empty lines and serving malformed (all-`[UNK]`) ones.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use hccs::coordinator::{BatchPolicy, EngineHandle, InferReply, ScoreConfig, ScoreEngine};
use hccs::data::{build_vocab, TaskKind, WorkloadGen};
use hccs::error::Result;
use hccs::hccs::{HccsParams, OutputPath, Reciprocal};
use hccs::model::{
    EncoderScratch, ModelConfig, NativeBackend, NativeModel, NativeServeConfig, SoftmaxBackend,
};
use hccs::server::{self, InferBackend};
use hccs::tokenizer::Tokenizer;

const N: usize = 32;

fn tokenizer() -> Tokenizer {
    let mut toks: Vec<String> = ["[PAD]", "[CLS]", "[SEP]", "[UNK]"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    for i in 0..N {
        toks.push(format!("t{i:03}"));
    }
    Tokenizer::from_tokens(toks).unwrap()
}

fn start_engine(shards: usize) -> (ScoreEngine, EngineHandle) {
    ScoreEngine::start(ScoreConfig {
        n: N,
        params: HccsParams::checked(300, 4, 16, N).unwrap(),
        out_path: OutputPath::I16,
        recip: Reciprocal::Div,
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        max_in_flight: None,
        shards,
    })
    .unwrap()
}

/// Logit position lit up for a tokenized request: its first real token
/// id, shifted past the 4 specials ([UNK] requests land on position 0).
fn hot_position(ids: &[i32]) -> usize {
    (ids.get(1).copied().unwrap_or(0).max(0) as usize).saturating_sub(4) % N
}

/// Adapter: tokenized request → synthetic int8 row → sharded scoring.
struct ScoreFront {
    engine: ScoreEngine,
    seq: AtomicU64,
}

impl InferBackend for ScoreFront {
    fn submit_request(
        &self,
        ids: Vec<i32>,
        _segments: Vec<i32>,
    ) -> Result<Receiver<Result<InferReply, String>>> {
        let mut row = vec![-60i8; N];
        row[hot_position(&ids)] = 60;
        let score_rx = self.engine.submit(row)?;
        let k = self.seq.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        // Bridge thread: map the score reply into an InferReply, after a
        // per-request jitter that scrambles delivery order.
        std::thread::spawn(move || {
            let reply = score_rx.recv();
            std::thread::sleep(Duration::from_millis((k * 7) % 23));
            let mapped = match reply {
                Ok(Ok(r)) => {
                    let logits: Vec<f32> =
                        r.phat.iter().map(|&v| v as f32 / 32767.0).collect();
                    let predicted = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(j, _)| j)
                        .unwrap_or(0);
                    Ok(InferReply { id: k, predicted, logits, latency: r.latency })
                }
                Ok(Err(e)) => Err(e),
                Err(_) => Err("score engine dropped request".to_string()),
            };
            let _ = tx.send(mapped);
        });
        Ok(rx)
    }
}

/// The serve input: request lines interleaved with comments, blanks,
/// and malformed (unknown-token) lines.  Returns (input, expected hot
/// positions of the lines that must be served, in input order).
fn build_input(tok: &Tokenizer, requests: usize) -> (String, Vec<usize>) {
    let max_len = TaskKind::Sst2s.max_len();
    let mut input = String::from("# leading comment\n\n");
    let mut lines: Vec<String> = Vec::new();
    for k in 0..requests {
        lines.push(format!("t{:03}", (requests - 1 - k) % N));
        if k % 5 == 2 {
            lines.push("# interleaved comment".to_string());
        }
        if k % 7 == 3 {
            lines.push(String::new());
        }
        if k % 11 == 4 {
            lines.push("??? totally unknown $tokens".to_string());
        }
    }
    let mut expected = Vec::new();
    for line in &lines {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            input.push_str(line);
            input.push('\n');
            continue;
        }
        let enc = server::encode_request(tok, TaskKind::Sst2s, t, max_len).unwrap();
        expected.push(hot_position(&enc.ids));
        input.push_str(line);
        input.push('\n');
    }
    (input, expected)
}

fn serve_through(shards: usize, input: &str, tok: &Tokenizer) -> (u64, String, ScoreEngine) {
    let (engine, handle) = start_engine(shards);
    let front = ScoreFront { engine: engine.clone(), seq: AtomicU64::new(0) };
    let mut out = Vec::new();
    let served = server::serve(
        &front,
        tok,
        TaskKind::Sst2s,
        std::io::BufReader::new(input.as_bytes()),
        &mut out,
    )
    .unwrap();
    engine.shutdown();
    handle.join().unwrap();
    (served, String::from_utf8(out).unwrap(), engine)
}

#[test]
fn multi_shard_serve_preserves_input_order_under_scrambled_completion() {
    let tok = tokenizer();
    let (input, expected) = build_input(&tok, 48);
    let (served, text, engine) = serve_through(4, &input, &tok);
    assert_eq!(served as usize, expected.len(), "comment/blank lines must be skipped");

    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), expected.len());
    for (i, (line, want)) in lines.iter().zip(&expected).enumerate() {
        let mut parts = line.split_whitespace();
        let predicted: usize = parts.next().unwrap().parse().unwrap();
        assert_eq!(
            predicted, *want,
            "line {i}: reply order diverged from input order"
        );
        let probs: Vec<f32> = parts.map(|p| p.parse().unwrap()).collect();
        assert_eq!(probs.len(), N);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-2);
    }

    // The workload must actually have exercised every shard.
    let m = &engine.metrics;
    assert_eq!(m.counter("scorer.requests").get(), served);
    for shard in 0..4 {
        let per = m.counter(&format!("scorer.requests.shard{shard}")).get();
        assert!(per > 0, "shard {shard} never served a request");
    }
    assert_eq!(m.sum_counters("scorer.requests.shard"), served);
}

#[test]
fn multi_shard_serve_output_is_identical_to_single_shard() {
    let tok = tokenizer();
    let (input, _) = build_input(&tok, 40);
    let (served1, text1, _) = serve_through(1, &input, &tok);
    let (served4, text4, _) = serve_through(4, &input, &tok);
    assert_eq!(served1, served4);
    assert_eq!(text1, text4, "sharding must not change served bytes");
}

// ---------------------------------------------------------------------------
// Native full-model backend through shards
// ---------------------------------------------------------------------------

/// One shared small native model (construction/calibration is the
/// expensive step; the serving tests only need *a* calibrated model).
fn native_model() -> Arc<NativeModel> {
    static MODEL: OnceLock<Arc<NativeModel>> = OnceLock::new();
    MODEL
        .get_or_init(|| {
            let task = TaskKind::Sst2s;
            let cfg = ModelConfig {
                layers: 2,
                heads: 2,
                d_model: 32,
                d_ff: 64,
                seq_len: task.max_len(),
                vocab: hccs::data::VOCAB_SIZE as usize,
                n_classes: 2,
            };
            Arc::new(NativeModel::new(cfg, task, 42).unwrap())
        })
        .clone()
}

fn native_backend(shards: usize, length_bands: usize) -> NativeBackend {
    NativeBackend::with_config(
        native_model(),
        SoftmaxBackend::parse("i16_div").unwrap(),
        NativeServeConfig {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            shards,
            length_bands,
            max_in_flight: None,
        },
    )
    .unwrap()
}

/// Text lines for the native server, covering distinct vocab words so
/// distinct requests produce distinct forwards.
fn native_input(requests: usize) -> String {
    let mut input = String::from("# native shard serving\n\n");
    for k in 0..requests {
        input.push_str(&format!(
            "w{:03} good{:02} not bad{:02} w{:03}\n",
            k % 40,
            k % 8,
            (k + 3) % 8,
            (requests - k) % 40
        ));
        if k % 6 == 2 {
            input.push_str("# interleaved comment\n");
        }
        if k % 9 == 4 {
            input.push('\n');
        }
    }
    input
}

/// `server::serve` through the sharded NativeBackend: the 4-shard
/// engine must emit byte-identical output to the 1-shard engine (reply
/// order == input order, and forward_batch bit-exactness means batch
/// composition cannot leak into the bytes), while actually spreading
/// work across every shard.
#[test]
fn native_multi_shard_serve_is_byte_identical_to_single_shard() {
    let tok = Tokenizer::from_tokens(build_vocab()).unwrap();
    let input = native_input(48);
    let mut outputs = Vec::new();
    for shards in [1usize, 4] {
        let backend = native_backend(shards, 1);
        let mut out = Vec::new();
        let served = server::serve(
            &backend,
            &tok,
            TaskKind::Sst2s,
            std::io::BufReader::new(input.as_bytes()),
            &mut out,
        )
        .unwrap();
        assert_eq!(served, 48, "{shards} shards served {served}");
        backend.shutdown();
        if shards == 4 {
            let m = &backend.metrics;
            assert_eq!(m.counter("native.requests").get(), 48);
            assert_eq!(m.sum_counters("native.requests.shard"), 48);
            for shard in 0..4 {
                let per = m.counter(&format!("native.requests.shard{shard}")).get();
                assert!(per > 0, "shard {shard} never served a request");
            }
            // The observed-batch-size histogram saw every flush.
            let bh = m.histogram("native.batch_rows");
            assert!(bh.count() >= 12, "only {} batches recorded", bh.count());
            assert!(bh.max_us() <= 4, "batch above max_batch recorded");
        }
        outputs.push(String::from_utf8(out).unwrap());
    }
    assert_eq!(outputs[0], outputs[1], "native sharding must not change served bytes");
}

/// Text lines with strongly varying word counts, so requests spread
/// across length bands and ragged batch compositions.
fn mixed_length_input(requests: usize) -> String {
    let mut input = String::from("# mixed-length traffic\n");
    for k in 0..requests {
        let words = 1 + (k * 5) % 17;
        let line: Vec<String> = (0..words).map(|j| format!("w{:03}", (k * 3 + j) % 40)).collect();
        input.push_str(&line.join(" "));
        input.push('\n');
        if k % 8 == 5 {
            input.push_str("# comment between lengths\n");
        }
    }
    input
}

/// End-to-end SIMD-dispatch parity through the serving stack: the same
/// mixed-length traffic served by a 4-shard, 2-length-band native
/// backend must produce **byte-identical** output under forced-scalar
/// dispatch and under the default (AVX2 where available) dispatch —
/// the whole vectorized surface (packed GEMM, masked gemm_nt/gemm_pv,
/// HCCS stages) pinned at the served-bytes level, under concurrent
/// shard workers and ragged band batching.
#[test]
fn native_forced_scalar_serve_is_byte_identical_to_default_dispatch() {
    let tok = Tokenizer::from_tokens(build_vocab()).unwrap();
    let input = mixed_length_input(48);
    let run = |force_scalar: bool| -> String {
        let _guard = force_scalar
            .then(|| hccs::simd::scoped_override(hccs::simd::SimdPath::Scalar));
        let backend = native_backend(4, 2);
        let mut out = Vec::new();
        let served = server::serve(
            &backend,
            &tok,
            TaskKind::Sst2s,
            std::io::BufReader::new(input.as_bytes()),
            &mut out,
        )
        .unwrap();
        assert_eq!(served, 48);
        backend.shutdown();
        String::from_utf8(out).unwrap()
    };
    let default_text = run(false);
    let forced_text = run(true);
    assert_eq!(
        default_text, forced_text,
        "forced-scalar dispatch changed served bytes under mixed-length traffic"
    );
}

/// Four jittered concurrent clients against a 4-shard native backend:
/// each client's replies must arrive in its submission order and be
/// bit-exact with a direct single-threaded `forward` of the same
/// inputs (per-request reply channels + batch-invariant forward_batch).
#[test]
fn native_concurrent_jittered_clients_get_ordered_bit_exact_replies() {
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 12;
    let model = native_model();
    let backend = Arc::new(native_backend(4, 1));
    let mode = SoftmaxBackend::parse("i16_div").unwrap();

    let mut joins = Vec::new();
    for client in 0..CLIENTS {
        let backend = backend.clone();
        joins.push(std::thread::spawn(move || {
            let task = TaskKind::Sst2s;
            let mut generator = WorkloadGen::new(task, 1000 + client as u64);
            let mut inputs = Vec::new();
            let mut rxs = Vec::new();
            for k in 0..PER_CLIENT {
                let ex = generator.next_example();
                rxs.push(backend.submit_request(ex.ids.clone(), ex.segments.clone()).unwrap());
                inputs.push((ex.ids, ex.segments));
                // Deterministic per-client jitter scrambles interleaving
                // across shards and batch flushes.
                let jitter_us = ((client * 7 + k * 3) % 11) as u64 * 100;
                std::thread::sleep(Duration::from_micros(jitter_us));
            }
            let replies: Vec<InferReply> = rxs
                .into_iter()
                .map(|rx| rx.recv().unwrap().expect("native inference ok"))
                .collect();
            (inputs, replies)
        }));
    }
    let mut scratch = EncoderScratch::default();
    for join in joins {
        let (inputs, replies) = join.join().unwrap();
        assert_eq!(replies.len(), PER_CLIENT);
        for (k, ((ids, segs), reply)) in inputs.iter().zip(&replies).enumerate() {
            let want = model.forward(ids, segs, mode, &mut scratch).unwrap();
            assert_eq!(reply.predicted, want.predicted, "client reply {k} out of order");
            assert_eq!(reply.logits, want.logits, "client reply {k} not bit-exact");
        }
    }
}
