//! Integration: the native full-model path, end to end and artifact
//! free — these tests **run** in CI (no skip path).
//!
//! Pins the repo's headline in-repo number: on the synthetic sst2s
//! eval stream, the per-head-calibrated HCCS i16+div backend agrees
//! with the f32-softmax reference on ≥ 90% of predictions (acceptance
//! band of EXPERIMENTS.md §encoder_e2e; the measured value at this
//! seed is ≈ 0.97, so the pin has real margin without being brittle).

use std::io::BufReader;
use std::sync::{Arc, OnceLock};

use hccs::data::{build_vocab, TaskKind};
use hccs::model::{
    eval_native, EncoderScratch, ModelConfig, NativeBackend, NativeModel, SoftmaxBackend,
};
use hccs::server::{self, InferBackend};
use hccs::tokenizer::Tokenizer;

/// The `hccs eval --task sst2s` setup at CI-sized eval scale.
const EVAL_LIMIT: usize = 64;
const MODEL_SEED: u64 = 42;

/// One shared bert-tiny build (calibration is the expensive step).
fn tiny_model() -> Arc<NativeModel> {
    static MODEL: OnceLock<Arc<NativeModel>> = OnceLock::new();
    MODEL
        .get_or_init(|| {
            let task = TaskKind::Sst2s;
            Arc::new(NativeModel::new(ModelConfig::bert_tiny(task), task, MODEL_SEED).unwrap())
        })
        .clone()
}

#[test]
fn hccs_agreement_band_holds_on_sst2s() {
    let model = tiny_model();
    let report = eval_native(&model, "bert-tiny", &SoftmaxBackend::hccs_modes(), EVAL_LIMIT)
        .unwrap();
    // Accuracy is reported for every backend and must be a sane
    // probability (the untrained synthetic model sits near chance).
    assert!((0.2..=0.8).contains(&report.reference_accuracy), "{report:?}");
    for m in &report.modes {
        assert!((0.0..=1.0).contains(&m.accuracy));
        // Every mode stays in a loose agreement band...
        assert!(
            m.agreement >= 0.85,
            "{} agreement {:.4} below the floor",
            m.backend.name(),
            m.agreement
        );
    }
    // ...and the acceptance-pinned mode clears 90%.
    let div = report.mode("i16_div").expect("i16_div evaluated");
    assert!(
        div.agreement >= 0.90,
        "i16_div agreement {:.4} < 0.90 over {EVAL_LIMIT} examples",
        div.agreement
    );
}

#[test]
fn eval_is_deterministic() {
    let model = tiny_model();
    let modes = [SoftmaxBackend::parse("i16_div").unwrap()];
    let a = eval_native(&model, "bert-tiny", &modes, 12).unwrap();
    let b = eval_native(&model, "bert-tiny", &modes, 12).unwrap();
    assert_eq!(a.reference_accuracy, b.reference_accuracy);
    assert_eq!(a.modes[0].accuracy, b.modes[0].accuracy);
    assert_eq!(a.modes[0].agreement, b.modes[0].agreement);
}

/// Full-model serving with zero artifacts: `server::serve` over a
/// [`NativeBackend`], real tokenizer built from the canonical vocab.
#[test]
fn native_backend_serves_text_protocol() {
    let task = TaskKind::Sst2s;
    let model = tiny_model();
    let tokenizer = Tokenizer::from_tokens(build_vocab()).unwrap();
    let backend =
        NativeBackend::new(model.clone(), SoftmaxBackend::parse("i16_div").unwrap());

    let input = "# native serving smoke\n\
                 good00 good01 w003 w004\n\
                 \n\
                 bad00 bad01 not good02 w000\n\
                 totally unknown tokens here\n\
                 w010 w011 w012 good05\n";
    let mut out = Vec::new();
    let served =
        server::serve(&backend, &tokenizer, task, BufReader::new(input.as_bytes()), &mut out)
            .unwrap();
    assert_eq!(served, 4, "comment/blank lines skipped, unknown tokens served");
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 4);
    for line in &lines {
        let mut parts = line.split_whitespace();
        let predicted: usize = parts.next().unwrap().parse().unwrap();
        assert!(predicted < task.n_classes());
        let probs: Vec<f32> = parts.map(|p| p.parse().unwrap()).collect();
        assert_eq!(probs.len(), task.n_classes());
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-2, "{line}");
    }

    // Serving is deterministic: a second pass produces identical bytes.
    let mut again = Vec::new();
    let backend2 = NativeBackend::new(model, SoftmaxBackend::parse("i16_div").unwrap());
    server::serve(&backend2, &tokenizer, task, BufReader::new(input.as_bytes()), &mut again)
        .unwrap();
    assert_eq!(text, String::from_utf8(again).unwrap());
}

/// The serving reply must reflect the same forward pass as a direct
/// model call (backend plumbing adds nothing).
#[test]
fn serving_reply_matches_direct_forward() {
    let task = TaskKind::Sst2s;
    let model = tiny_model();
    let tokenizer = Tokenizer::from_tokens(build_vocab()).unwrap();
    let mode = SoftmaxBackend::parse("i8_clb").unwrap();
    let backend = NativeBackend::new(model.clone(), mode);

    let enc = server::encode_request(&tokenizer, task, "good00 not bad03 w001", 64).unwrap();
    let (ids, segs) = (enc.ids, enc.segments);
    let reply = backend
        .submit_request(ids.clone(), segs.clone())
        .unwrap()
        .recv()
        .unwrap()
        .expect("native inference ok");
    let mut scratch = EncoderScratch::default();
    let direct = model.forward(&ids, &segs, mode, &mut scratch).unwrap();
    assert_eq!(reply.predicted, direct.predicted);
    assert_eq!(reply.logits, direct.logits);
}
