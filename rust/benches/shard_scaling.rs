//! Bench target for the **sharded coordinator**: multi-shard
//! [`ScoreEngine`] throughput (rows/s) at `shards ∈ {1, 2, 4, 8}` for
//! the paper's two headline modes, with a fixed open-loop client pool
//! so the only variable is the shard count.  On a multi-core host,
//! rows/s must rise monotonically from 1 to 4 shards (the CI
//! acceptance shape); 8 may flatten once the host runs out of cores.
//!
//! Prints one table row per (mode, shards) with measured rows/s, the
//! speedup vs one shard, and the `aie_sim::MultiTileSim` projected
//! speedup for the same shard count (dispatch-aware, so it also
//! flattens — at the feeder's issue bound rather than the core count).
//! Then emits a machine-readable JSON document (see EXPERIMENTS.md
//! §shard_scaling) and, when `HCCS_BENCH_JSON` is set, writes it to
//! `BENCH_shard_scaling.json` for the CI bench trajectory.

use hccs::aie_sim::{Device, DeviceKind, KernelKind, MultiTileSim};
use hccs::benchkit::{bench, write_json};
use hccs::coordinator::{BatchPolicy, EngineHandle, ScoreConfig, ScoreEngine};
use hccs::hccs::{hccs_row, HccsParams, OutputPath, Reciprocal};
use hccs::json::Value;
use hccs::report::Table;
use hccs::rng::Xoshiro256;
use std::time::Duration;

const N: usize = 256;
const SHARDS: [usize; 4] = [1, 2, 4, 8];
const CLIENTS: usize = 4;
const ROWS_PER_CLIENT: usize = 512;

fn theta() -> HccsParams {
    let (lo, hi) = HccsParams::feasible_b_band(1, 16, N).expect("band");
    HccsParams::checked((lo + hi) / 2, 1, 16, N).unwrap()
}

fn engine(mode: (&str, OutputPath, Reciprocal), shards: usize) -> (ScoreEngine, EngineHandle) {
    ScoreEngine::start(ScoreConfig {
        n: N,
        params: theta(),
        out_path: mode.1,
        recip: mode.2,
        policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(1) },
        max_in_flight: None,
        shards,
    })
    .expect("engine start")
}

/// Simulated dispatch-aware speedup for `shards` on the AIE model.
fn sim_speedup(kernel: KernelKind, shards: usize, tiles: u64) -> f64 {
    let d = Device::new(DeviceKind::AieMlV2);
    let serial = hccs::aie_sim::cycles_per_tile(kernel, &d, 64, N) * tiles;
    let mut m = MultiTileSim::new(d, kernel, shards);
    for _ in 0..tiles {
        m.dispatch_tile(64, N);
    }
    serial as f64 / m.makespan_cycles() as f64
}

fn main() {
    let host = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!("host parallelism: {host} (need > 1 for shard speedup)");
    let modes: [(&str, OutputPath, Reciprocal, KernelKind); 2] = [
        ("i16_div", OutputPath::I16, Reciprocal::Div, KernelKind::HccsI16Div),
        ("i8_clb", OutputPath::I8, Reciprocal::Clb, KernelKind::HccsI8Clb),
    ];

    // Per-client request pools, reused (cloned) every iteration.
    let mut rng = Xoshiro256::new(31);
    let pools: Vec<Vec<Vec<i8>>> = (0..CLIENTS)
        .map(|_| {
            (0..ROWS_PER_CLIENT)
                .map(|_| (0..N).map(|_| rng.i8()).collect())
                .collect()
        })
        .collect();
    let probe_rows: Vec<Vec<i8>> = (0..8).map(|_| (0..N).map(|_| rng.i8()).collect()).collect();
    let total_rows = (CLIENTS * ROWS_PER_CLIENT) as f64;

    let mut table = Table::new(
        "sharded ScoreEngine throughput (rows/s, this machine)",
        &["mode", "shards", "rows/s", "speedup", "sim speedup (AIE)"],
    );
    let mut cases: Vec<Value> = Vec::new();

    for (mode, op, rc, kernel) in modes {
        let mut base_rps = 0.0f64;
        for shards in SHARDS {
            let (eng, handle) = engine((mode, op, rc), shards);

            // Bit-exactness alongside the measurement: sharded serving
            // must match the row kernel for every shard count.
            for x in &probe_rows {
                let got = eng.score(x.clone()).expect("probe scored").phat;
                assert_eq!(got, hccs_row(x, &theta(), op, rc), "{mode} shards={shards}");
            }

            let r = bench(&format!("{mode} shards={shards}"), || {
                std::thread::scope(|s| {
                    for pool in &pools {
                        let eng = eng.clone();
                        s.spawn(move || {
                            let rxs: Vec<_> = pool
                                .iter()
                                .map(|x| eng.submit(x.clone()).expect("submit"))
                                .collect();
                            for rx in rxs {
                                rx.recv().expect("reply").expect("scored");
                            }
                        });
                    }
                });
            });
            eng.shutdown();
            handle.join().unwrap();

            let rps = r.per_second(total_rows);
            if shards == 1 {
                base_rps = rps;
            }
            let speedup = rps / base_rps;
            let sim = sim_speedup(kernel, shards, 256);
            table.row(&[
                mode.to_string(),
                shards.to_string(),
                format!("{rps:.3e}"),
                format!("{speedup:.2}x"),
                format!("{sim:.2}x"),
            ]);

            let mut case = std::collections::BTreeMap::new();
            case.insert("mode".to_string(), Value::from(mode));
            case.insert("shards".to_string(), Value::from(shards as i64));
            case.insert("rows_per_s".to_string(), Value::from(rps));
            case.insert("speedup_vs_1".to_string(), Value::from(speedup));
            case.insert("sim_speedup".to_string(), Value::from(sim));
            case.insert("median_ns".to_string(), Value::from(r.median.as_nanos() as i64));
            cases.push(Value::Obj(case));
        }
    }

    println!("{}", table.render());

    let mut doc = std::collections::BTreeMap::new();
    doc.insert("bench".to_string(), Value::from("shard_scaling"));
    doc.insert("units".to_string(), Value::from("rows_per_second"));
    doc.insert("n".to_string(), Value::from(N as i64));
    doc.insert("clients".to_string(), Value::from(CLIENTS as i64));
    doc.insert("host_parallelism".to_string(), Value::from(host as i64));
    doc.insert("cases".to_string(), Value::Arr(cases));
    let doc = Value::Obj(doc);
    println!("{}", doc.to_string_pretty());
    write_json("shard_scaling", &doc);
}
