//! Ablation bench: dynamic-batching policy frontier.
//!
//! DESIGN.md calls out the size/deadline batching policy as the main L3
//! design choice; this harness sweeps (max_batch × max_wait) against the
//! real bert-tiny HCCS executable and prints the throughput/latency
//! frontier, plus the backpressure shed behaviour under overload.
//! Skips when artifacts are missing.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use hccs::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use hccs::data::{TaskKind, WorkloadGen};
use hccs::report::Table;

fn artifacts_dir() -> Option<PathBuf> {
    ["artifacts", "../artifacts"]
        .iter()
        .map(PathBuf::from)
        .find(|p| p.join("vocab.json").exists())
}

fn run_policy(
    artifacts: &Path,
    max_batch: usize,
    wait_ms: u64,
    n_req: usize,
) -> Option<(f64, u64, u64)> {
    let (coord, handle) = Coordinator::start(CoordinatorConfig {
        artifacts: artifacts.to_path_buf(),
        model: "bert-tiny".into(),
        task: "sst2s".into(),
        variant: "hccs".into(),
        policy: BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        },
        max_in_flight: None,
        shards: 1,
    })
    .ok()?;
    let mut generator = WorkloadGen::new(TaskKind::Sst2s, 42);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_req)
        .map(|_| {
            let e = generator.next_example();
            coord.submit(e.ids, e.segments).unwrap()
        })
        .collect();
    let mut lat: Vec<u64> = rxs
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap().latency.as_micros() as u64)
        .collect();
    let wall = t0.elapsed();
    lat.sort();
    coord.shutdown();
    let _ = handle.join();
    Some((
        n_req as f64 / wall.as_secs_f64(),
        lat[n_req / 2],
        lat[n_req * 99 / 100],
    ))
}

fn main() {
    let Some(artifacts) = artifacts_dir() else {
        println!("SKIP policy_ablation: no artifacts");
        return;
    };
    if hccs::runtime::manifest::summary_path(&artifacts, "bert-tiny", "sst2s").is_none() {
        println!("SKIP policy_ablation: bert-tiny/sst2s not built yet");
        return;
    }

    // NOTE: the exported executables are b1 and b8; the engine requires a
    // matching manifest, so the sweep covers those two batch shapes with
    // several deadlines — the deadline axis only matters under partial
    // load, which the open-loop burst below creates for small waits.
    let mut t = Table::new(
        "batching policy frontier (bert-tiny/sst2s hccs, 128-request burst)",
        &["max_batch", "deadline ms", "req/s", "p50 us", "p99 us"],
    );
    for &(mb, wait) in &[(1usize, 0u64), (1, 5), (8, 0), (8, 2), (8, 5), (8, 20)] {
        if let Some((rps, p50, p99)) = run_policy(&artifacts, mb, wait, 128) {
            t.row(&[
                mb.to_string(),
                wait.to_string(),
                format!("{rps:.1}"),
                p50.to_string(),
                p99.to_string(),
            ]);
        }
    }
    println!("{}", t.render());

    // Backpressure: bounded in-flight sheds instead of queueing.
    let (coord, handle) = Coordinator::start(CoordinatorConfig {
        artifacts: artifacts.clone(),
        model: "bert-tiny".into(),
        task: "sst2s".into(),
        variant: "hccs".into(),
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        max_in_flight: Some(32),
        shards: 1,
    })
    .unwrap();
    let mut generator = WorkloadGen::new(TaskKind::Sst2s, 7);
    let mut accepted = Vec::new();
    let mut shed = 0usize;
    for _ in 0..512 {
        let e = generator.next_example();
        match coord.submit(e.ids, e.segments) {
            Ok(rx) => accepted.push(rx),
            Err(_) => shed += 1,
        }
    }
    let served = accepted.into_iter().filter(|rx| rx.recv().is_ok()).count();
    println!(
        "backpressure (max_in_flight=32): {served} served, {shed} shed at admission, \
         {} recorded by the controller",
        coord.shed_count()
    );
    assert_eq!(served + shed, 512, "requests must be conserved");
    coord.shutdown();
    let _ = handle.join();
}
