//! Microbenchmarks of the coordinator substrate: dynamic batcher ops and
//! metrics recording — these sit on the per-request hot path, so their
//! cost must be negligible next to model execution (§Perf L3 criterion).

use std::time::{Duration, Instant};

use hccs::benchkit::{bench, sink};
use hccs::coordinator::{BatchPolicy, DynamicBatcher};
use hccs::metrics::Histogram;

fn main() {
    println!("== batcher/metrics microbenchmarks ==");

    // push+flush cycle at batch 8.
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
    let mut b: DynamicBatcher<u64> = DynamicBatcher::new(policy);
    let now = Instant::now();
    let r = bench("batcher push (flush every 8th)", || {
        if let Some(batch) = b.push(1, now) {
            sink(batch.items.len());
        }
    });
    println!("{}  -> {:.1} M req/s", r.render(), r.per_second(1.0) / 1e6);

    // Deadline polling on a non-empty queue.
    let mut b2: DynamicBatcher<u64> = DynamicBatcher::new(BatchPolicy {
        max_batch: 1024,
        max_wait: Duration::from_secs(3600),
    });
    b2.push(1, now);
    let r = bench("batcher poll (deadline not due)", || {
        sink(b2.poll(now).is_some());
    });
    println!("{}", r.render());

    // Histogram record (two per request on the serving path).
    let h = Histogram::new();
    let d = Duration::from_micros(1234);
    let r = bench("histogram record", || {
        h.record(sink(d));
    });
    println!("{}  -> {:.1} M records/s", r.render(), r.per_second(1.0) / 1e6);

    let r = bench("histogram p99 query", || {
        sink(h.percentile_us(99.0));
    });
    println!("{}", r.render());
}
