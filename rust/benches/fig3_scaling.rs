//! Bench target for **Fig. 3**: multi-tile scaling sweep on AIE-MLv2 and
//! the simulator's own row-processing throughput (it must stay far above
//! what any harness needs — the §Perf L3 criterion for aie_sim).

use hccs::aie_sim::device::{Device, DeviceKind};
use hccs::aie_sim::kernels::KernelKind;
use hccs::aie_sim::{scaling, tile::TileSim};
use hccs::benchkit::{bench, sink};
use hccs::experiments;

fn main() {
    println!("{}", experiments::fig3().unwrap());

    let dev = Device::new(DeviceKind::AieMlV2);
    let r = bench("fig3 full sweep (both kernels, 1..184 tiles)", || {
        sink(scaling::sweep(&dev, KernelKind::HccsI16Div, 128, dev.array_tiles));
        sink(scaling::sweep(&dev, KernelKind::HccsI8Clb, 128, dev.array_tiles));
    });
    println!("{}", r.render());

    // The tile model is closed-form per (rows, n) batch, so a workload of
    // any size costs one process() call — bench the call itself plus a
    // mixed-length workload loop (4096 batches of varying n).
    let sim = TileSim::new(dev, KernelKind::HccsI8Clb);
    let r = bench("tile model: process() one batch", || {
        let mut s = sim.clone();
        s.process(1_000_000, 128);
        sink(s.total_cycles());
    });
    println!("{}", r.render());
    let lengths: Vec<usize> = (0..4096).map(|i| 16 + (i % 241)).collect();
    let r = bench("tile model: 4096 mixed-length batches", || {
        let mut s = sim.clone();
        for &n in &lengths {
            s.process(64, n);
        }
        sink(s.throughput_eps());
    });
    println!("{}  -> {:.1} M batches/s", r.render(), r.per_second(4096.0) / 1e6);
}
