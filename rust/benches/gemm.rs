//! Bench target for the **linalg packed GEMM core**: blocked
//! [`PackedGemm`] vs the scalar reference oracle on the encoder's real
//! shapes, plus a batch-axis row sweep showing how stacking activation
//! rows (what `NativeModel::forward_batch` does) amortizes the packed
//! panel streaming, plus a **fused-epilogue sweep** on the bert-small
//! shapes (`gemm_fused_into` vs the standalone requant/residual/LN
//! sweeps it replaced — `fused_speedup` is gated ≥ 1.1x by CI
//! bench-smoke, warn < 1.25x).
//!
//! With the SIMD dispatch layer, every case additionally times the
//! packed kernel on **both** dispatch paths (AVX2 vs forced-scalar,
//! same algorithm) and reports `simd_speedup_vs_scalar_path` — the
//! number the CI bench-smoke gate checks (≥ 1.5× on the bert-small
//! shapes, warn < 2.5×) — plus `roofline_pct`, the measured fraction of
//! one modeled AIE-MLv2 tile's MAC throughput on the same shape
//! (tracked by `tools/bench_trend.py`).
//!
//! Prints one table row per shape with MMAC/s for both kernels and the
//! speedup, then a machine-readable JSON document (see EXPERIMENTS.md
//! §gemm for the schema).  When `HCCS_BENCH_JSON` is set the document
//! is also written to `BENCH_gemm.json`; budgets honor
//! `HCCS_BENCH_*_MS`.  Every case asserts packed == scalar (and AVX2
//! path == scalar path) before timing, so the bench doubles as an
//! oracle smoke test.

use hccs::aie_sim::gemm::{mac_utilization, GemmShape};
use hccs::aie_sim::{roofline, Device, DeviceKind};
use hccs::benchkit::{bench, sink, write_json};
use hccs::json::Value;
use hccs::linalg::{layernorm_rows, matmul_i8_ref, requant, Epilogue, PackedGemm};
use hccs::report::Table;
use hccs::rng::Xoshiro256;
use hccs::simd::{self, SimdPath};

/// Encoder shapes: bert-tiny/-small projections, FFN halves, and a
/// classifier-style skinny GEMM ((m, k, n) = activations (m, k) times
/// weights (n, k)).
const SHAPES: [(&str, usize, usize, usize); 6] = [
    ("tiny proj 64x64x64", 64, 64, 64),
    ("tiny ffn-up 64x64x128", 64, 64, 128),
    ("tiny ffn-down 64x128x64", 64, 128, 64),
    ("small proj 128x128x128", 128, 128, 128),
    ("small ffn-up 128x128x256", 128, 128, 256),
    ("classifier 1x64x2", 1, 64, 2),
];

fn main() {
    let mut rng = Xoshiro256::new(2024);
    let device = Device::new(DeviceKind::AieMlV2);
    let avx2 = simd::avx2_available();
    let mut table = Table::new(
        "packed GEMM vs scalar oracle (this machine)",
        &["shape", "scalar MMAC/s", "packed MMAC/s", "speedup", "simd/x", "roofline", "aie MAC%"],
    );
    let mut cases: Vec<Value> = Vec::new();

    for (name, m, k, n) in SHAPES {
        let x: Vec<i8> = (0..m * k).map(|_| rng.i8()).collect();
        let w: Vec<i8> = (0..n * k).map(|_| rng.i8()).collect();
        let packed = PackedGemm::pack(&w, n, k);
        // Oracle check before timing: the bench never reports a number
        // for a kernel that disagrees with the reference — on either
        // dispatch path.
        let (mut got, mut want) = (Vec::new(), Vec::new());
        packed.gemm_into(&x, &mut got);
        matmul_i8_ref(&x, k, &w, n, &mut want);
        assert_eq!(got, want, "{name}: packed GEMM diverged from the scalar oracle");
        if avx2 {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            packed.gemm_into_with_path(SimdPath::Avx2, &x, &mut a);
            packed.gemm_into_with_path(SimdPath::Scalar, &x, &mut b);
            assert_eq!(a, b, "{name}: AVX2 path diverged from the scalar path");
        }

        let macs = (m * k * n) as f64;
        let mut out = Vec::new();
        let rs = bench(&format!("scalar {name}"), || {
            matmul_i8_ref(&x, k, &w, n, &mut out);
            sink(out.len());
        });
        let rp = bench(&format!("packed {name}"), || {
            packed.gemm_into(&x, &mut out);
            sink(out.len());
        });
        // Forced-path pair: the honest SIMD speedup (same blocked
        // algorithm, only the lane implementation differs).
        let rpath_scalar = bench(&format!("packed/scalar-path {name}"), || {
            packed.gemm_into_with_path(SimdPath::Scalar, &x, &mut out);
            sink(out.len());
        });
        let path_scalar_mps = rpath_scalar.per_second(macs) / 1e6;
        let simd_speedup = if avx2 {
            let rpath_avx2 = bench(&format!("packed/avx2-path {name}"), || {
                packed.gemm_into_with_path(SimdPath::Avx2, &x, &mut out);
                sink(out.len());
            });
            rpath_avx2.per_second(macs) / 1e6 / path_scalar_mps.max(1e-9)
        } else {
            1.0
        };
        let scalar_mps = rs.per_second(macs) / 1e6;
        let packed_mps = rp.per_second(macs) / 1e6;
        let speedup = packed_mps / scalar_mps.max(1e-9);
        let shape = GemmShape::new(m, k, n);
        let modeled_mps = roofline::modeled_mmacs(&device, &shape);
        let roofline_pct = 100.0 * packed_mps / modeled_mps.max(1e-9);
        table.row(&[
            name.to_string(),
            format!("{scalar_mps:.0}"),
            format!("{packed_mps:.0}"),
            format!("{speedup:.2}x"),
            if avx2 { format!("{simd_speedup:.2}x") } else { "n/a".to_string() },
            format!("{roofline_pct:.1}%"),
            format!("{:.0}%", mac_utilization(&device, &shape) * 100.0),
        ]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("name".to_string(), Value::from(name));
        case.insert("m".to_string(), Value::from(m as i64));
        case.insert("k".to_string(), Value::from(k as i64));
        case.insert("n".to_string(), Value::from(n as i64));
        case.insert("scalar_macs_per_s".to_string(), Value::from(scalar_mps * 1e6));
        case.insert("packed_macs_per_s".to_string(), Value::from(packed_mps * 1e6));
        case.insert("speedup_vs_scalar".to_string(), Value::from(speedup));
        case.insert(
            "simd_speedup_vs_scalar_path".to_string(),
            Value::from(simd_speedup),
        );
        case.insert("roofline_pct".to_string(), Value::from(roofline_pct));
        case.insert("macro_tiles".to_string(), Value::from(shape.macro_tiles() as i64));
        cases.push(Value::Obj(case));
    }
    println!("{}", table.render());

    // Batch-axis row sweep: one packed weight, growing activation row
    // counts — the GEMM-side source of the forward_batch win.
    let (k, n) = (64usize, 64usize);
    let w: Vec<i8> = (0..n * k).map(|_| rng.i8()).collect();
    let packed = PackedGemm::pack(&w, n, k);
    let mut sweep: Vec<Value> = Vec::new();
    let mut sweep_table =
        Table::new("packed GEMM row sweep (k=64, n=64)", &["rows", "MMAC/s", "vs 1 row"]);
    let mut one_row = 0.0f64;
    for rows in [1usize, 4, 16, 64, 256] {
        let x: Vec<i8> = (0..rows * k).map(|_| rng.i8()).collect();
        let mut out = Vec::new();
        let r = bench(&format!("packed rows={rows}"), || {
            packed.gemm_into(&x, &mut out);
            sink(out.len());
        });
        let mps = r.per_second((rows * k * n) as f64) / 1e6;
        if rows == 1 {
            one_row = mps;
        }
        sweep_table.row(&[
            rows.to_string(),
            format!("{mps:.0}"),
            format!("{:.2}x", mps / one_row.max(1e-9)),
        ]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("rows".to_string(), Value::from(rows as i64));
        case.insert("macs_per_s".to_string(), Value::from(mps * 1e6));
        case.insert("speedup_vs_one_row".to_string(), Value::from(mps / one_row.max(1e-9)));
        sweep.push(Value::Obj(case));
    }
    println!("{}", sweep_table.render());

    // Fused-epilogue sweep on the bert-small encoder shapes: the fused
    // kernel applies requant → residual add → LayerNorm to each MC-row
    // block while the i32 accumulator is still cache-resident; the
    // unfused leg is the standalone-sweep composition it replaced
    // (same vectorized kernels, extra full-tile round trips).
    // Bit-equality is asserted before timing.  CI bench-smoke gates
    // `fused_speedup` — the geomean of the residual+LN shapes, where
    // fusion deletes the most traffic — at ≥ 1.1x (warn < 1.25x); the
    // ReLU-only case is reported in the sweep but ungated.
    const FUSED_SHAPES: [(&str, usize, usize, usize, bool); 3] = [
        ("small proj+res+LN 128x128x128", 128, 128, 128, true),
        ("small ffn-up+ReLU 128x128x256", 128, 128, 256, false),
        ("small ffn-down+res+LN 128x256x128", 128, 256, 128, true),
    ];
    let mut fused_table = Table::new(
        "fused epilogue vs standalone sweeps (bert-small shapes)",
        &["shape", "unfused MMAC/s", "fused MMAC/s", "speedup"],
    );
    let mut fused_sweep: Vec<Value> = Vec::new();
    let mut gated_speedup = 1.0f64;
    let mut gated_shapes = 0u32;
    for (name, m, k, n, with_ln) in FUSED_SHAPES {
        let x: Vec<i8> = (0..m * k).map(|_| rng.i8()).collect();
        let w: Vec<i8> = (0..n * k).map(|_| rng.i8()).collect();
        let packed = PackedGemm::pack(&w, n, k);
        let div = 97i32;
        let residual: Vec<i8> = (0..m * n).map(|_| rng.i8()).collect();
        let gamma: Vec<i8> = (0..n).map(|_| 48 + rng.below(33) as i8).collect();
        let beta: Vec<i8> = (0..n).map(|_| (rng.below(17) as i64 - 8) as i8).collect();
        let ep = if with_ln {
            Epilogue::RequantResidualLn { div, residual: &residual, gamma: &gamma, beta: &beta }
        } else {
            Epilogue::RequantRelu { div }
        };
        let mut acc: Vec<i32> = Vec::new();
        let mut t8: Vec<i8> = Vec::new();
        let mut x32: Vec<i32> = Vec::new();
        let mut unfused = |out: &mut Vec<i8>| {
            packed.gemm_into(&x, &mut acc);
            requant(&acc, div, &mut t8);
            if with_ln {
                x32.clear();
                x32.extend(residual.iter().zip(&t8).map(|(&r, &b)| i32::from(r) + i32::from(b)));
                layernorm_rows(&x32, n, &gamma, &beta, out);
            } else {
                out.clear();
                out.extend(t8.iter().map(|&v| v.max(0)));
            }
        };
        let mut fused_out: Vec<i8> = Vec::new();
        packed.gemm_fused_into(&x, &ep, &mut fused_out);
        let mut want: Vec<i8> = Vec::new();
        unfused(&mut want);
        assert_eq!(fused_out, want, "{name}: fused epilogue diverged from the standalone sweeps");

        let macs = (m * k * n) as f64;
        let mut out: Vec<i8> = Vec::new();
        let ru = bench(&format!("unfused {name}"), || {
            unfused(&mut out);
            sink(out.len());
        });
        let rf = bench(&format!("fused {name}"), || {
            packed.gemm_fused_into(&x, &ep, &mut fused_out);
            sink(fused_out.len());
        });
        let unfused_mps = ru.per_second(macs) / 1e6;
        let fused_mps = rf.per_second(macs) / 1e6;
        let speedup = fused_mps / unfused_mps.max(1e-9);
        if with_ln {
            gated_speedup *= speedup;
            gated_shapes += 1;
        }
        fused_table.row(&[
            name.to_string(),
            format!("{unfused_mps:.0}"),
            format!("{fused_mps:.0}"),
            format!("{speedup:.2}x"),
        ]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("name".to_string(), Value::from(name));
        case.insert("m".to_string(), Value::from(m as i64));
        case.insert("k".to_string(), Value::from(k as i64));
        case.insert("n".to_string(), Value::from(n as i64));
        case.insert("gated".to_string(), Value::from(with_ln));
        case.insert("unfused_macs_per_s".to_string(), Value::from(unfused_mps * 1e6));
        case.insert("fused_macs_per_s".to_string(), Value::from(fused_mps * 1e6));
        case.insert("fused_speedup_vs_unfused".to_string(), Value::from(speedup));
        fused_sweep.push(Value::Obj(case));
    }
    let fused_speedup = gated_speedup.powf(1.0 / f64::from(gated_shapes.max(1)));
    println!("{}", fused_table.render());

    // Worker-pool sweep on a tall tile: the intra-op scaling of one
    // gemm_into pass (thread counts beyond the host's cores simply
    // converge to the core-bound rate).
    let (pk, pn, prows) = (128usize, 128usize, 512usize);
    let pw: Vec<i8> = (0..pn * pk).map(|_| rng.i8()).collect();
    let ppacked = PackedGemm::pack(&pw, pn, pk);
    let px: Vec<i8> = (0..prows * pk).map(|_| rng.i8()).collect();
    let mut pool_sweep: Vec<Value> = Vec::new();
    let mut pool_table = Table::new(
        "worker-pool sweep (512x128x128, one gemm_into pass)",
        &["threads", "MMAC/s", "vs 1 thread"],
    );
    let mut one_thread = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let pool = hccs::runtime::pool::WorkerPool::new(threads);
        let mut out = Vec::new();
        let r = hccs::runtime::pool::with_pool(&pool, || {
            bench(&format!("pool threads={threads}"), || {
                ppacked.gemm_into(&px, &mut out);
                sink(out.len());
            })
        });
        let mps = r.per_second((prows * pk * pn) as f64) / 1e6;
        if threads == 1 {
            one_thread = mps;
        }
        pool_table.row(&[
            threads.to_string(),
            format!("{mps:.0}"),
            format!("{:.2}x", mps / one_thread.max(1e-9)),
        ]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("threads".to_string(), Value::from(threads as i64));
        case.insert("macs_per_s".to_string(), Value::from(mps * 1e6));
        case.insert(
            "speedup_vs_one_thread".to_string(),
            Value::from(mps / one_thread.max(1e-9)),
        );
        pool_sweep.push(Value::Obj(case));
    }
    println!("{}", pool_table.render());

    let mut doc = std::collections::BTreeMap::new();
    doc.insert("bench".to_string(), Value::from("gemm"));
    doc.insert("units".to_string(), Value::from("macs_per_second"));
    doc.insert("avx2_available".to_string(), Value::from(avx2));
    doc.insert("active_path".to_string(), Value::from(simd::active().name()));
    doc.insert("fused_speedup".to_string(), Value::from(fused_speedup));
    doc.insert(
        "bytes_moved_ratio".to_string(),
        Value::from(hccs::aie_sim::bytes::bytes_moved_ratio(
            &hccs::model::ModelConfig::bert_small(hccs::data::TaskKind::Mnlis),
            128,
        )),
    );
    doc.insert("cases".to_string(), Value::Arr(cases));
    doc.insert("row_sweep".to_string(), Value::Arr(sweep));
    doc.insert("fused_sweep".to_string(), Value::Arr(fused_sweep));
    doc.insert("pool_sweep".to_string(), Value::Arr(pool_sweep));
    let doc = Value::Obj(doc);
    println!("{}", doc.to_string_pretty());
    write_json("gemm", &doc);
}
