//! Bench target for the **linalg packed GEMM core**: blocked
//! [`PackedGemm`] vs the scalar reference oracle on the encoder's real
//! shapes, plus a batch-axis row sweep showing how stacking activation
//! rows (what `NativeModel::forward_batch` does) amortizes the packed
//! panel streaming.
//!
//! With the SIMD dispatch layer, every case additionally times the
//! packed kernel on **both** dispatch paths (AVX2 vs forced-scalar,
//! same algorithm) and reports `simd_speedup_vs_scalar_path` — the
//! number the CI bench-smoke gate checks (≥ 1.5× on the bert-small
//! shapes, warn < 2.5×) — plus `roofline_pct`, the measured fraction of
//! one modeled AIE-MLv2 tile's MAC throughput on the same shape
//! (tracked by `tools/bench_trend.py`).
//!
//! Prints one table row per shape with MMAC/s for both kernels and the
//! speedup, then a machine-readable JSON document (see EXPERIMENTS.md
//! §gemm for the schema).  When `HCCS_BENCH_JSON` is set the document
//! is also written to `BENCH_gemm.json`; budgets honor
//! `HCCS_BENCH_*_MS`.  Every case asserts packed == scalar (and AVX2
//! path == scalar path) before timing, so the bench doubles as an
//! oracle smoke test.

use hccs::aie_sim::gemm::{mac_utilization, GemmShape};
use hccs::aie_sim::{roofline, Device, DeviceKind};
use hccs::benchkit::{bench, sink, write_json};
use hccs::json::Value;
use hccs::linalg::{matmul_i8_ref, PackedGemm};
use hccs::report::Table;
use hccs::rng::Xoshiro256;
use hccs::simd::{self, SimdPath};

/// Encoder shapes: bert-tiny/-small projections, FFN halves, and a
/// classifier-style skinny GEMM ((m, k, n) = activations (m, k) times
/// weights (n, k)).
const SHAPES: [(&str, usize, usize, usize); 6] = [
    ("tiny proj 64x64x64", 64, 64, 64),
    ("tiny ffn-up 64x64x128", 64, 64, 128),
    ("tiny ffn-down 64x128x64", 64, 128, 64),
    ("small proj 128x128x128", 128, 128, 128),
    ("small ffn-up 128x128x256", 128, 128, 256),
    ("classifier 1x64x2", 1, 64, 2),
];

fn main() {
    let mut rng = Xoshiro256::new(2024);
    let device = Device::new(DeviceKind::AieMlV2);
    let avx2 = simd::avx2_available();
    let mut table = Table::new(
        "packed GEMM vs scalar oracle (this machine)",
        &["shape", "scalar MMAC/s", "packed MMAC/s", "speedup", "simd/x", "roofline", "aie MAC%"],
    );
    let mut cases: Vec<Value> = Vec::new();

    for (name, m, k, n) in SHAPES {
        let x: Vec<i8> = (0..m * k).map(|_| rng.i8()).collect();
        let w: Vec<i8> = (0..n * k).map(|_| rng.i8()).collect();
        let packed = PackedGemm::pack(&w, n, k);
        // Oracle check before timing: the bench never reports a number
        // for a kernel that disagrees with the reference — on either
        // dispatch path.
        let (mut got, mut want) = (Vec::new(), Vec::new());
        packed.gemm_into(&x, &mut got);
        matmul_i8_ref(&x, k, &w, n, &mut want);
        assert_eq!(got, want, "{name}: packed GEMM diverged from the scalar oracle");
        if avx2 {
            let (mut a, mut b) = (Vec::new(), Vec::new());
            packed.gemm_into_with_path(SimdPath::Avx2, &x, &mut a);
            packed.gemm_into_with_path(SimdPath::Scalar, &x, &mut b);
            assert_eq!(a, b, "{name}: AVX2 path diverged from the scalar path");
        }

        let macs = (m * k * n) as f64;
        let mut out = Vec::new();
        let rs = bench(&format!("scalar {name}"), || {
            matmul_i8_ref(&x, k, &w, n, &mut out);
            sink(out.len());
        });
        let rp = bench(&format!("packed {name}"), || {
            packed.gemm_into(&x, &mut out);
            sink(out.len());
        });
        // Forced-path pair: the honest SIMD speedup (same blocked
        // algorithm, only the lane implementation differs).
        let rpath_scalar = bench(&format!("packed/scalar-path {name}"), || {
            packed.gemm_into_with_path(SimdPath::Scalar, &x, &mut out);
            sink(out.len());
        });
        let path_scalar_mps = rpath_scalar.per_second(macs) / 1e6;
        let simd_speedup = if avx2 {
            let rpath_avx2 = bench(&format!("packed/avx2-path {name}"), || {
                packed.gemm_into_with_path(SimdPath::Avx2, &x, &mut out);
                sink(out.len());
            });
            rpath_avx2.per_second(macs) / 1e6 / path_scalar_mps.max(1e-9)
        } else {
            1.0
        };
        let scalar_mps = rs.per_second(macs) / 1e6;
        let packed_mps = rp.per_second(macs) / 1e6;
        let speedup = packed_mps / scalar_mps.max(1e-9);
        let shape = GemmShape::new(m, k, n);
        let modeled_mps = roofline::modeled_mmacs(&device, &shape);
        let roofline_pct = 100.0 * packed_mps / modeled_mps.max(1e-9);
        table.row(&[
            name.to_string(),
            format!("{scalar_mps:.0}"),
            format!("{packed_mps:.0}"),
            format!("{speedup:.2}x"),
            if avx2 { format!("{simd_speedup:.2}x") } else { "n/a".to_string() },
            format!("{roofline_pct:.1}%"),
            format!("{:.0}%", mac_utilization(&device, &shape) * 100.0),
        ]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("name".to_string(), Value::from(name));
        case.insert("m".to_string(), Value::from(m as i64));
        case.insert("k".to_string(), Value::from(k as i64));
        case.insert("n".to_string(), Value::from(n as i64));
        case.insert("scalar_macs_per_s".to_string(), Value::from(scalar_mps * 1e6));
        case.insert("packed_macs_per_s".to_string(), Value::from(packed_mps * 1e6));
        case.insert("speedup_vs_scalar".to_string(), Value::from(speedup));
        case.insert(
            "simd_speedup_vs_scalar_path".to_string(),
            Value::from(simd_speedup),
        );
        case.insert("roofline_pct".to_string(), Value::from(roofline_pct));
        case.insert("macro_tiles".to_string(), Value::from(shape.macro_tiles() as i64));
        cases.push(Value::Obj(case));
    }
    println!("{}", table.render());

    // Batch-axis row sweep: one packed weight, growing activation row
    // counts — the GEMM-side source of the forward_batch win.
    let (k, n) = (64usize, 64usize);
    let w: Vec<i8> = (0..n * k).map(|_| rng.i8()).collect();
    let packed = PackedGemm::pack(&w, n, k);
    let mut sweep: Vec<Value> = Vec::new();
    let mut sweep_table =
        Table::new("packed GEMM row sweep (k=64, n=64)", &["rows", "MMAC/s", "vs 1 row"]);
    let mut one_row = 0.0f64;
    for rows in [1usize, 4, 16, 64, 256] {
        let x: Vec<i8> = (0..rows * k).map(|_| rng.i8()).collect();
        let mut out = Vec::new();
        let r = bench(&format!("packed rows={rows}"), || {
            packed.gemm_into(&x, &mut out);
            sink(out.len());
        });
        let mps = r.per_second((rows * k * n) as f64) / 1e6;
        if rows == 1 {
            one_row = mps;
        }
        sweep_table.row(&[
            rows.to_string(),
            format!("{mps:.0}"),
            format!("{:.2}x", mps / one_row.max(1e-9)),
        ]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("rows".to_string(), Value::from(rows as i64));
        case.insert("macs_per_s".to_string(), Value::from(mps * 1e6));
        case.insert("speedup_vs_one_row".to_string(), Value::from(mps / one_row.max(1e-9)));
        sweep.push(Value::Obj(case));
    }
    println!("{}", sweep_table.render());

    // Worker-pool sweep on a tall tile: the intra-op scaling of one
    // gemm_into pass (thread counts beyond the host's cores simply
    // converge to the core-bound rate).
    let (pk, pn, prows) = (128usize, 128usize, 512usize);
    let pw: Vec<i8> = (0..pn * pk).map(|_| rng.i8()).collect();
    let ppacked = PackedGemm::pack(&pw, pn, pk);
    let px: Vec<i8> = (0..prows * pk).map(|_| rng.i8()).collect();
    let mut pool_sweep: Vec<Value> = Vec::new();
    let mut pool_table = Table::new(
        "worker-pool sweep (512x128x128, one gemm_into pass)",
        &["threads", "MMAC/s", "vs 1 thread"],
    );
    let mut one_thread = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let pool = hccs::runtime::pool::WorkerPool::new(threads);
        let mut out = Vec::new();
        let r = hccs::runtime::pool::with_pool(&pool, || {
            bench(&format!("pool threads={threads}"), || {
                ppacked.gemm_into(&px, &mut out);
                sink(out.len());
            })
        });
        let mps = r.per_second((prows * pk * pn) as f64) / 1e6;
        if threads == 1 {
            one_thread = mps;
        }
        pool_table.row(&[
            threads.to_string(),
            format!("{mps:.0}"),
            format!("{:.2}x", mps / one_thread.max(1e-9)),
        ]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("threads".to_string(), Value::from(threads as i64));
        case.insert("macs_per_s".to_string(), Value::from(mps * 1e6));
        case.insert(
            "speedup_vs_one_thread".to_string(),
            Value::from(mps / one_thread.max(1e-9)),
        );
        pool_sweep.push(Value::Obj(case));
    }
    println!("{}", pool_table.render());

    let mut doc = std::collections::BTreeMap::new();
    doc.insert("bench".to_string(), Value::from("gemm"));
    doc.insert("units".to_string(), Value::from("macs_per_second"));
    doc.insert("avx2_available".to_string(), Value::from(avx2));
    doc.insert("active_path".to_string(), Value::from(simd::active().name()));
    doc.insert("cases".to_string(), Value::Arr(cases));
    doc.insert("row_sweep".to_string(), Value::Arr(sweep));
    doc.insert("pool_sweep".to_string(), Value::Arr(pool_sweep));
    let doc = Value::Obj(doc);
    println!("{}", doc.to_string_pretty());
    write_json("gemm", &doc);
}
