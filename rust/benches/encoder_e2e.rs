//! Bench target for the **native full-model path**: end-to-end
//! examples/s of the integer encoder under every softmax backend
//! (f32 reference vs all four HCCS modes), on the real bert-tiny
//! shapes.
//!
//! Prints one table row per backend with examples/s, speedup vs the
//! f32 reference, and the backend's prediction agreement on the bench
//! workload, then a **batch-axis sweep**: `forward_batch` examples/s at
//! batch ∈ {1, 2, 4, 8, 16} on the pinned i16_div mode, showing the
//! stacked-GEMM + single-HCCS-dispatch-per-head win over the
//! one-example baseline, then a **length-distribution sweep**:
//! examples/s at avg_len/max_len ∈ {0.25, 0.5, 0.75, 1.0} (synthetic
//! examples padded to the full task width), showing the valid-length
//! masked path's speedup tracking the density ratio, and a
//! **fused-vs-unfused epilogue leg**: the same batch-8 workload with
//! GEMM epilogue fusion forced on and off (`fused_speedup`, gated in
//! CI, next to the modeled `bytes_moved_ratio`).  Ends with a
//! machine-readable JSON document (see EXPERIMENTS.md §encoder_e2e for
//! the schema, including the `batch_sweep` and `length_sweep` arrays
//! and the whole-encoder `roofline_pct` / `host_gemm_macs_per_s`
//! measured-vs-modeled fields tracked by `tools/bench_trend.py`).
//! When `HCCS_BENCH_JSON` is set the document is also written to
//! `BENCH_encoder_e2e.json`; budgets honor `HCCS_BENCH_*_MS`.

use hccs::aie_sim::bytes::bytes_moved_ratio;
use hccs::aie_sim::gemm::{encoder_gemm_cycles, encoder_gemms, encoder_macro_tiles};
use hccs::aie_sim::trace::EncoderTrace;
use hccs::aie_sim::{Device, DeviceKind};
use hccs::benchkit::{bench, sink, write_json};
use hccs::data::{TaskKind, WorkloadGen};
use hccs::json::Value;
use hccs::linalg::scoped_fused;
use hccs::model::{eval_native, EncoderScratch, ModelConfig, NativeModel, SoftmaxBackend};
use hccs::report::Table;

const BENCH_EXAMPLES: usize = 32;
const AGREEMENT_EXAMPLES: usize = 32;

fn main() {
    let task = TaskKind::Sst2s;
    let cfg = ModelConfig::bert_tiny(task);
    eprintln!("calibrating native bert-tiny/{}...", task.name());
    let model = NativeModel::new(cfg, task, 42).expect("model build");
    // Shapes for the AIE capacity projection come from the actual
    // model config, not hardcoded values.
    let trace = EncoderTrace::from_config(&cfg);

    let mut generator = WorkloadGen::new(task, 7);
    let examples: Vec<_> = (0..BENCH_EXAMPLES).map(|_| generator.next_example()).collect();

    let backends: Vec<SoftmaxBackend> = std::iter::once(SoftmaxBackend::F32Ref)
        .chain(SoftmaxBackend::hccs_modes())
        .collect();
    let agreement = eval_native(
        &model,
        "bert-tiny",
        &SoftmaxBackend::hccs_modes(),
        AGREEMENT_EXAMPLES,
    )
    .expect("agreement eval");

    let mut table = Table::new(
        "native encoder end-to-end (bert-tiny/sst2s, this machine)",
        &["backend", "examples/s", "vs f32", "agreement"],
    );
    let mut cases: Vec<Value> = Vec::new();
    let mut f32_eps = 0.0f64;
    for backend in backends {
        let mut scratch = EncoderScratch::default();
        let mut i = 0usize;
        let r = bench(&format!("encoder {}", backend.name()), || {
            let ex = &examples[i % examples.len()];
            i += 1;
            let inf = model
                .forward(&ex.ids, &ex.segments, backend, &mut scratch)
                .expect("forward");
            sink(inf.predicted);
        });
        let eps = r.per_second(1.0);
        if backend == SoftmaxBackend::F32Ref {
            f32_eps = eps;
        }
        let agree = agreement.mode(backend.name()).map(|m| m.agreement);
        table.row(&[
            backend.name().to_string(),
            format!("{eps:.1}"),
            format!("{:.2}x", eps / f32_eps.max(1e-9)),
            agree.map_or("(reference)".to_string(), |a| format!("{a:.4}")),
        ]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("backend".to_string(), Value::from(backend.name()));
        case.insert("examples_per_s".to_string(), Value::from(eps));
        case.insert("median_ns".to_string(), Value::from(r.median.as_nanos() as i64));
        case.insert(
            "speedup_vs_f32".to_string(),
            Value::from(eps / f32_eps.max(1e-9)),
        );
        if let Some(a) = agree {
            case.insert("agreement_vs_f32".to_string(), Value::from(a));
        }
        cases.push(Value::Obj(case));
    }
    println!("{}", table.render());

    // Batch-axis sweep: the same examples, stacked `bs` at a time into
    // one forward_batch call (bit-exact with per-example forward —
    // proptest-pinned — so this measures pure batching efficiency).
    let sweep_backend = SoftmaxBackend::parse("i16_div").expect("known mode");
    let mut sweep_table = Table::new(
        "forward_batch batch-size sweep (i16_div)",
        &["batch", "examples/s", "vs batch=1"],
    );
    let mut sweep: Vec<Value> = Vec::new();
    let mut scratch = EncoderScratch::default();
    let mut b1_eps = 0.0f64;
    let mut b16_eps = 0.0f64;
    for &bs in &[1usize, 2, 4, 8, 16] {
        let mut ids = Vec::with_capacity(bs * model.cfg.seq_len);
        let mut segs = Vec::with_capacity(bs * model.cfg.seq_len);
        for ex in examples.iter().cycle().take(bs) {
            ids.extend_from_slice(&ex.ids);
            segs.extend_from_slice(&ex.segments);
        }
        let r = bench(&format!("forward_batch b={bs}"), || {
            let inferences = model
                .forward_batch(&ids, &segs, sweep_backend, &mut scratch)
                .expect("forward_batch");
            sink(inferences.len());
        });
        let eps = r.per_second(bs as f64);
        if bs == 1 {
            b1_eps = eps;
        }
        if bs == 16 {
            b16_eps = eps;
        }
        let speedup = eps / b1_eps.max(1e-9);
        sweep_table.row(&[bs.to_string(), format!("{eps:.1}"), format!("{speedup:.2}x")]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("batch".to_string(), Value::from(bs as i64));
        case.insert("examples_per_s".to_string(), Value::from(eps));
        case.insert("speedup_vs_b1".to_string(), Value::from(speedup));
        sweep.push(Value::Obj(case));
    }
    println!("{}", sweep_table.render());

    // Fused-vs-unfused epilogue dataflow: the same batch-8 workload
    // with the GEMM epilogue fusion forced on and off (the unfused leg
    // is the standalone requant/residual/LayerNorm sweep dataflow —
    // bit-exact by the differential/proptest pins, so this measures
    // pure memory traffic).  `bytes_moved_ratio` is the aie_sim model
    // of the same gap.
    const FUSED_BATCH: usize = 8;
    let mut fused_eps = 0.0f64;
    let mut unfused_eps = 0.0f64;
    {
        let mut ids = Vec::with_capacity(FUSED_BATCH * model.cfg.seq_len);
        let mut segs = Vec::with_capacity(FUSED_BATCH * model.cfg.seq_len);
        for ex in examples.iter().cycle().take(FUSED_BATCH) {
            ids.extend_from_slice(&ex.ids);
            segs.extend_from_slice(&ex.segments);
        }
        for (label, on) in [("fused", true), ("unfused", false)] {
            let _guard = scoped_fused(on);
            let r = bench(&format!("epilogue {label} b={FUSED_BATCH}"), || {
                let inferences = model
                    .forward_batch(&ids, &segs, sweep_backend, &mut scratch)
                    .expect("forward_batch");
                sink(inferences.len());
            });
            let eps = r.per_second(FUSED_BATCH as f64);
            if on {
                fused_eps = eps;
            } else {
                unfused_eps = eps;
            }
        }
    }
    let fused_speedup = fused_eps / unfused_eps.max(1e-9);
    let modeled_bytes_ratio = bytes_moved_ratio(&cfg, cfg.seq_len);
    println!(
        "fused epilogues: {fused_eps:.1} vs {unfused_eps:.1} examples/s unfused \
         ({fused_speedup:.2}x measured; modeled bytes-moved ratio {modeled_bytes_ratio:.2}x)"
    );

    // Length-distribution sweep: synthetic examples at a controlled
    // valid length, padded to the full task width and run through
    // forward_batch at a fixed batch size — so the measured speedup is
    // purely the masked path skipping pad rows/keys (density ratio),
    // with dense (1.0) as the baseline.  Densities descend so the
    // baseline is measured first.
    const LENGTH_SWEEP_BATCH: usize = 8;
    let seq = model.cfg.seq_len;
    let mut len_table = Table::new(
        &format!("valid-length sweep (i16_div, batch {LENGTH_SWEEP_BATCH}, max_len {seq})"),
        &["avg/max", "valid tokens", "examples/s", "vs dense"],
    );
    let mut len_sweep: Vec<Value> = Vec::new();
    let mut dense_eps = 0.0f64;
    let mut filler = hccs::rng::Xoshiro256::new(4242);
    for &density in &[1.0f64, 0.75, 0.5, 0.25] {
        let valid = ((seq as f64 * density).round() as usize).clamp(3, seq);
        // [CLS] + fillers + [SEP], padded to the full width.
        let mut ids = Vec::with_capacity(LENGTH_SWEEP_BATCH * seq);
        let mut segs = Vec::with_capacity(LENGTH_SWEEP_BATCH * seq);
        for _ in 0..LENGTH_SWEEP_BATCH {
            let mut ex = vec![0i32; seq];
            ex[0] = 1; // [CLS]
            for slot in ex[1..valid - 1].iter_mut() {
                *slot = 4 + filler.below(150) as i32;
            }
            ex[valid - 1] = 2; // [SEP]
            ids.extend_from_slice(&ex);
            segs.extend(std::iter::repeat_n(0i32, seq));
        }
        let r = bench(&format!("length sweep d={density:.2}"), || {
            let inferences = model
                .forward_batch(&ids, &segs, sweep_backend, &mut scratch)
                .expect("forward_batch");
            sink(inferences.len());
        });
        let eps = r.per_second(LENGTH_SWEEP_BATCH as f64);
        if density == 1.0 {
            dense_eps = eps;
        }
        let speedup = eps / dense_eps.max(1e-9);
        len_table.row(&[
            format!("{density:.2}"),
            valid.to_string(),
            format!("{eps:.1}"),
            format!("{speedup:.2}x"),
        ]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("density".to_string(), Value::from(density));
        case.insert("avg_len".to_string(), Value::from(valid as i64));
        case.insert("max_len".to_string(), Value::from(seq as i64));
        case.insert("examples_per_s".to_string(), Value::from(eps));
        case.insert("speedup_vs_dense".to_string(), Value::from(speedup));
        case.insert(
            "gemm_macro_tiles".to_string(),
            Value::from(hccs::aie_sim::gemm::encoder_macro_tiles_at(&cfg, valid) as i64),
        );
        len_sweep.push(Value::Obj(case));
    }
    println!("{}", len_table.render());

    // Host-vs-model roofline on the whole-encoder GEMM workload: what
    // fraction of one modeled AIE-MLv2 tile's GEMM-only inference rate
    // the measured batch-16 end-to-end rate achieves.  The host number
    // also pays embedding/HCCS/layernorm time the model ignores, so
    // this is a conservative lower bound on the GEMM-core gap.
    let device = Device::new(DeviceKind::AieMlV2);
    let macs_per_example: u64 =
        encoder_gemms(&cfg).iter().map(|(_, s, calls)| calls * s.macs()).sum();
    let modeled_gemm_inf_per_s =
        device.freq_ghz * 1e9 / encoder_gemm_cycles(&device, &cfg) as f64;
    let host_gemm_macs_per_s = b16_eps * macs_per_example as f64;
    let roofline_pct = 100.0 * b16_eps / modeled_gemm_inf_per_s.max(1e-9);
    println!(
        "roofline: host batch-16 {} = {:.1} examples/s ({:.0} MMAC/s of encoder GEMM work) \
         vs one modeled AIE-MLv2 tile at {:.1} GEMM-only inferences/s -> {:.2}% of modeled",
        sweep_backend.name(),
        b16_eps,
        host_gemm_macs_per_s / 1e6,
        modeled_gemm_inf_per_s,
        roofline_pct
    );

    let mut doc = std::collections::BTreeMap::new();
    doc.insert("bench".to_string(), Value::from("encoder_e2e"));
    doc.insert("model".to_string(), Value::from("bert-tiny"));
    doc.insert("task".to_string(), Value::from(task.name()));
    doc.insert("units".to_string(), Value::from("examples_per_second"));
    doc.insert("softmax_rows_per_example".to_string(), Value::from(trace.rows() as i64));
    doc.insert(
        "gemm_macro_tiles_per_example".to_string(),
        Value::from(encoder_macro_tiles(&cfg) as i64),
    );
    doc.insert(
        "agreement_examples".to_string(),
        Value::from(AGREEMENT_EXAMPLES as i64),
    );
    doc.insert("simd_path".to_string(), Value::from(hccs::simd::active().name()));
    doc.insert("host_gemm_macs_per_s".to_string(), Value::from(host_gemm_macs_per_s));
    doc.insert(
        "modeled_gemm_inf_per_s".to_string(),
        Value::from(modeled_gemm_inf_per_s),
    );
    doc.insert("roofline_pct".to_string(), Value::from(roofline_pct));
    doc.insert("fused_speedup".to_string(), Value::from(fused_speedup));
    doc.insert("unfused_examples_per_s".to_string(), Value::from(unfused_eps));
    doc.insert("bytes_moved_ratio".to_string(), Value::from(modeled_bytes_ratio));
    doc.insert("cases".to_string(), Value::Arr(cases));
    doc.insert("batch_sweep".to_string(), Value::Arr(sweep));
    doc.insert("length_sweep".to_string(), Value::Arr(len_sweep));
    let doc = Value::Obj(doc);
    println!("{}", doc.to_string_pretty());
    write_json("encoder_e2e", &doc);
}
