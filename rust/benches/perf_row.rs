//! Perf experiment behind the row kernel's design choice: three
//! candidate implementations of the five HCCS stages — (A) the current
//! two-pass structure, (B) a scores-buffer three-pass variant, (C) a
//! per-row 256-entry LUT gather.  See EXPERIMENTS.md §Perf for how to
//! read the results.
use hccs::benchkit::{bench, sink};
use hccs::hccs::{hccs_row_into, HccsParams, OutputPath, Reciprocal};
use hccs::rng::Xoshiro256;

// Variant B: scores buffer reused (3 passes, no recompute)  [current = A]
fn variant_b(x: &[i8], p: &HccsParams, out: &mut [i32]) {
    let mut m = i8::MIN;
    for &v in x {
        m = m.max(v);
    }
    let m = m as i32;
    let mut z = 0i32;
    for (o, &xi) in out.iter_mut().zip(x) {
        let s = p.b - p.s * (m - xi as i32).min(p.dmax);
        *o = s;
        z += s;
    }
    let rho = 32767 / z;
    for o in out.iter_mut() {
        *o *= rho;
    }
}

// Variant C: 256-entry score LUT built per row, then gather.
fn variant_c(x: &[i8], p: &HccsParams, out: &mut [i32], lut: &mut [i32; 256]) {
    let mut m = i8::MIN;
    for &v in x {
        m = m.max(v);
    }
    let m = m as i32;
    for q in -128i32..128 {
        lut[(q + 128) as usize] = p.b - p.s * (m - q).min(p.dmax);
    }
    let mut z = 0i32;
    for (o, &xi) in out.iter_mut().zip(x) {
        let s = lut[(xi as i32 + 128) as usize];
        *o = s;
        z += s;
    }
    let rho = 32767 / z;
    for o in out.iter_mut() {
        *o *= rho;
    }
}

fn main() {
    let mut rng = Xoshiro256::new(5);
    for n in [32usize, 64, 128, 512] {
        let (lo, hi) = HccsParams::feasible_b_band(1, 16, n).expect("band");
        let p = HccsParams::checked((lo + hi) / 2, 1, 16, n).unwrap();
        let x: Vec<i8> = (0..n).map(|_| rng.i8()).collect();
        let mut out = vec![0i32; n];
        let mut lut = [0i32; 256];
        let a = bench(&format!("A current n={n}"), || {
            hccs_row_into(sink(&x), &p, OutputPath::I16, Reciprocal::Div, &mut out)
        });
        let b = bench(&format!("B fused    n={n}"), || variant_b(sink(&x), &p, &mut out));
        let c = bench(&format!("C lut      n={n}"), || variant_c(sink(&x), &p, &mut out, &mut lut));
        println!("{}\n{}\n{}", a.render(), b.render(), c.render());
    }
}
