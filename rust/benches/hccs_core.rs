//! Microbenchmarks of the Rust HCCS hot path (benchkit, harness=false):
//! row kernel across lengths/modes, batched rows, and the calibration
//! grid search.  These are the §Perf L3 numbers in EXPERIMENTS.md.

use hccs::benchkit::{bench, sink};
use hccs::hccs::calibrate::{calibrate_rows, calibrate_scale};
use hccs::hccs::{hccs_row_into, hccs_rows, HccsParams, OutputPath, Reciprocal};
use hccs::rng::Xoshiro256;

fn main() {
    let mut rng = Xoshiro256::new(5);
    println!("== hccs_core microbenchmarks ==");

    for n in [32usize, 64, 128, 512] {
        // (S=1, Dmax=16) keeps the Eq. (11) band non-empty out to n=512.
        let (lo, hi) = HccsParams::feasible_b_band(1, 16, n).expect("band");
        let theta = HccsParams::checked((lo + hi) / 2, 1, 16, n).unwrap();
        let x: Vec<i8> = (0..n).map(|_| rng.i8()).collect();
        let mut out = vec![0i32; n];
        for (label, op, rc) in [
            ("i16+div", OutputPath::I16, Reciprocal::Div),
            ("i8+clb", OutputPath::I8, Reciprocal::Clb),
        ] {
            let r = bench(&format!("hccs_row n={n} {label}"), || {
                hccs_row_into(sink(&x), &theta, op, rc, &mut out);
            });
            println!("{}  -> {:.1} M elem/s", r.render(), r.per_second(n as f64) / 1e6);
        }
    }

    // Batched rows with per-row θ (the serving layout: heads x queries).
    let n = 64usize;
    let rows = 256usize;
    let theta = HccsParams::checked(300, 4, 64, n).unwrap();
    let params = vec![theta; rows];
    let x: Vec<i8> = (0..rows * n).map(|_| rng.i8()).collect();
    let r = bench("hccs_rows 256x64 i16+div", || {
        sink(hccs_rows(&x, n, &params, OutputPath::I16, Reciprocal::Div));
    });
    println!("{}  -> {:.1} M elem/s", r.render(), r.per_second((rows * n) as f64) / 1e6);

    // Calibration grid search (offline path, but must stay interactive).
    let rows_f: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..n).map(|_| (rng.f64() + rng.f64() - 1.0) * 4.0).collect())
        .collect();
    let gamma = calibrate_scale(&rows_f.iter().flatten().cloned().collect::<Vec<_>>(), 99.9);
    let r = bench("calibrate_rows 64x64 full grid", || {
        sink(calibrate_rows(&rows_f, n, gamma));
    });
    println!("{}", r.render());
}
