//! Bench target for **Tables I/II** (the accuracy-table harness) and the
//! end-to-end serving path: measures PJRT model-execute latency, the
//! coordinator overhead on top of it, and eval throughput per variant.
//!
//! Requires `make artifacts`; prints SKIP lines otherwise so `cargo
//! bench` stays green on a fresh checkout.

use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use hccs::benchkit::{bench_with, sink};
use hccs::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use hccs::data::{TaskKind, WorkloadGen};
use hccs::runtime::{manifest::summary_path, ModelRunner, PairSummary, Runtime};

fn artifacts_dir() -> PathBuf {
    for base in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(base);
        if p.join("vocab.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

fn main() {
    let artifacts = artifacts_dir();
    let Some(spath) = summary_path(&artifacts, "bert-tiny", "sst2s") else {
        println!("SKIP serving_e2e: no artifacts (run `make artifacts`)");
        return;
    };
    let summary = PairSummary::load(&spath).unwrap();

    // 1. Raw PJRT execute latency, float vs HCCS variant, b1 and b8.
    println!("== raw model execute (PJRT, bert-tiny/sst2s) ==");
    let rt = Rc::new(Runtime::cpu().unwrap());
    let mut generator = WorkloadGen::new(TaskKind::Sst2s, 3);
    for variant in ["float", "hccs"] {
        for b in [1usize, 8] {
            let Some(mani) = summary.manifest(variant, b) else { continue };
            let runner = ModelRunner::load(rt.clone(), &artifacts, mani.clone()).unwrap();
            let _l = runner.seq_len();
            let mut ids = Vec::new();
            let mut segs = Vec::new();
            for _ in 0..b {
                let e = generator.next_example();
                ids.extend(e.ids);
                segs.extend(e.segments);
            }
            let r = bench_with(
                &format!("execute {variant} b{b}"),
                std::time::Duration::from_millis(200),
                std::time::Duration::from_millis(600),
                &mut || {
                    sink(runner.run(&ids, &segs).unwrap());
                },
            );
            println!(
                "{}  -> {:.1} examples/s",
                r.render(),
                r.per_second(b as f64)
            );
        }
    }

    // 2. Coordinator overhead: same model behind the batcher.
    println!("\n== coordinator end-to-end (batch 8, 5ms deadline) ==");
    let (coord, handle) = Coordinator::start(CoordinatorConfig {
        artifacts: artifacts.clone(),
        model: "bert-tiny".into(),
        task: "sst2s".into(),
        variant: "hccs".into(),
        policy: BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(5) },
        max_in_flight: None,
        shards: 1,
    })
    .unwrap();
    let mut generator = WorkloadGen::new(TaskKind::Sst2s, 17);
    let n_req = 512usize;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_req)
        .map(|_| {
            let e = generator.next_example();
            coord.submit(e.ids, e.segments).unwrap()
        })
        .collect();
    let mut lat_us: Vec<u64> = rxs
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap().latency.as_micros() as u64)
        .collect();
    let wall = t0.elapsed();
    lat_us.sort();
    println!(
        "  {n_req} requests in {wall:?} -> {:.1} req/s; latency p50 {}us p95 {}us p99 {}us",
        n_req as f64 / wall.as_secs_f64(),
        lat_us[n_req / 2],
        lat_us[n_req * 95 / 100],
        lat_us[n_req * 99 / 100],
    );
    coord.shutdown();
    let _ = handle.join();

    // 3. Tables I/II accuracy harness timing (the "bench" of an accuracy
    // table is its regeneration cost).
    println!("\n== table regeneration ==");
    let t0 = Instant::now();
    let t1 = hccs::experiments::table1(&artifacts, 64, true).unwrap();
    println!("table1 (re-measured over 64 examples/variant): {:?}\n{t1}", t0.elapsed());
    let t0 = Instant::now();
    let t2 = hccs::experiments::table2(&artifacts).unwrap();
    println!("table2: {:?}\n{t2}", t0.elapsed());
}
