//! End-to-end serving bench: the **overload sweep** that gates graceful
//! degradation in CI, plus the artifact-gated PJRT latency sections.
//!
//! The sweep runs the artifact-free native backend behind the sharded
//! batching engine with a per-request deadline, measures closed-loop
//! peak throughput, then offers open-loop load at 0.5x / 1x / 2x peak
//! and reports goodput (completed within deadline), shed fraction, and
//! latency percentiles per point.  The CI contract (`bench-smoke`):
//!
//! * `goodput_rows_per_s` at 2x offered load stays >= 0.8x peak — the
//!   engine sheds expired work instead of collapsing under a backlog;
//! * `shed` > 0 at 2x — overload is actually being shed, not queued;
//! * `p99_us` at 2x stays bounded — deadline shedding caps queue wait.
//!
//! Writes `BENCH_serving_e2e.json` when `HCCS_BENCH_JSON` is set (the
//! schema is documented in `EXPERIMENTS.md`); honors the
//! `HCCS_BENCH_WARMUP_MS` / `HCCS_BENCH_MEASURE_MS` budget overrides.
//!
//! The PJRT sections (raw model execute, coordinator overhead, table
//! regeneration) still require `make artifacts` and print SKIP lines
//! otherwise, so `cargo bench` stays green on a fresh checkout.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use hccs::benchkit::{bench_with, budgets, sink, write_json};
use hccs::coordinator::{is_shed_error, BatchPolicy, Coordinator, CoordinatorConfig, InferReply};
use hccs::data::{TaskKind, WorkloadGen};
use hccs::json::{obj, Value};
use hccs::model::{ModelConfig, NativeBackend, NativeModel, NativeServeConfig, SoftmaxBackend};
use hccs::runtime::{manifest::summary_path, ModelRunner, PairSummary, Runtime};
use hccs::server::InferBackend;

/// Per-request SLO for the sweep.  Must dwarf `max_wait` (1ms) so the
/// deadline bites on *queue backlog*, not on routine batching delay.
const DEADLINE: Duration = Duration::from_millis(25);
const WINDOW: usize = 64;
const OFFERED_X: [f64; 3] = [0.5, 1.0, 2.0];

fn artifacts_dir() -> PathBuf {
    for base in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(base);
        if p.join("vocab.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// Pre-tokenized request pool (the sweep measures serving, not
/// tokenization).
fn request_pool(task: TaskKind, n: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
    let mut generator = WorkloadGen::new(task, 3);
    (0..n)
        .map(|_| {
            let e = generator.next_example();
            (e.ids, e.segments)
        })
        .collect()
}

fn native_backend() -> NativeBackend {
    let task = TaskKind::Sst2s;
    let cfg = ModelConfig {
        layers: 1,
        heads: 2,
        d_model: 32,
        d_ff: 64,
        seq_len: task.max_len(),
        vocab: hccs::data::VOCAB_SIZE as usize,
        n_classes: 2,
    };
    let model = std::sync::Arc::new(NativeModel::new(cfg, task, 42).unwrap());
    NativeBackend::with_config(
        model,
        SoftmaxBackend::parse("i16_div").unwrap(),
        NativeServeConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            shards: 2,
            length_bands: 1,
            // Effectively uncapped: the sweep exercises *deadline*
            // shedding at flush time, not the admission occupancy gate.
            max_in_flight: Some(4096),
        },
    )
    .unwrap()
}

/// Closed-loop peak: keep `WINDOW` requests in flight (no deadline) and
/// count completions per second — the capacity the sweep offers
/// multiples of.
fn measure_peak(backend: &NativeBackend, pool: &[(Vec<i32>, Vec<i32>)], budget: Duration) -> f64 {
    let t0 = Instant::now();
    let mut inflight: VecDeque<Receiver<Result<InferReply, String>>> = VecDeque::new();
    let mut k = 0usize;
    let submit = |inflight: &mut VecDeque<_>, k: &mut usize| {
        let (ids, segs) = pool[*k % pool.len()].clone();
        *k += 1;
        inflight.push_back(backend.submit_request(ids, segs).expect("peak submit"));
    };
    for _ in 0..WINDOW {
        submit(&mut inflight, &mut k);
    }
    let mut done = 0u64;
    while t0.elapsed() < budget {
        let rx = inflight.pop_front().expect("window never empties");
        rx.recv().expect("engine alive").expect("no deadline => no shed");
        done += 1;
        submit(&mut inflight, &mut k);
    }
    for rx in inflight {
        rx.recv().expect("engine alive").expect("no deadline => no shed");
        done += 1;
    }
    done as f64 / t0.elapsed().as_secs_f64()
}

struct SweepPoint {
    offered_x: f64,
    offered_rows_per_s: f64,
    goodput_rows_per_s: f64,
    shed_fraction: f64,
    completed: u64,
    shed: u64,
    p50_us: u64,
    p99_us: u64,
}

/// Open-loop point: pace submissions at `offered` rows/s with a
/// `DEADLINE` SLO on each, drain replies on a second thread, and
/// classify completed vs shed.
fn sweep_point(
    backend: &NativeBackend,
    pool: &[(Vec<i32>, Vec<i32>)],
    peak: f64,
    offered_x: f64,
    budget: Duration,
) -> SweepPoint {
    let offered = (peak * offered_x).max(1.0);
    let (tx, rx) = std::sync::mpsc::channel::<Receiver<Result<InferReply, String>>>();
    let drainer = std::thread::spawn(move || {
        let (mut completed, mut shed) = (0u64, 0u64);
        let mut lat_us: Vec<u64> = Vec::new();
        for reply_rx in rx {
            match reply_rx.recv().expect("engine alive") {
                Ok(reply) => {
                    completed += 1;
                    lat_us.push(reply.latency.as_micros() as u64);
                }
                Err(msg) if is_shed_error(&msg) => shed += 1,
                Err(msg) => panic!("non-shed serving error: {msg}"),
            }
        }
        lat_us.sort_unstable();
        (completed, shed, lat_us)
    });

    let t0 = Instant::now();
    let mut submitted = 0u64;
    let mut shed_at_admission = 0u64;
    while t0.elapsed() < budget {
        // Pace to the offered rate: sleep until this request's slot.
        let target = Duration::from_secs_f64(submitted as f64 / offered);
        if let Some(wait) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let (ids, segs) = pool[submitted as usize % pool.len()].clone();
        match backend.submit_with_deadline(ids, segs, Some(Instant::now() + DEADLINE)) {
            Ok(reply_rx) => tx.send(reply_rx).expect("drainer alive"),
            Err(e) if is_shed_error(&format!("{e}")) => shed_at_admission += 1,
            Err(e) => panic!("non-shed submit error: {e:#}"),
        }
        submitted += 1;
    }
    drop(tx);
    let wall = t0.elapsed();
    let (completed, shed_at_flush, lat_us) = drainer.join().expect("drainer");
    let shed = shed_at_flush + shed_at_admission;
    let pct = |q: usize| -> u64 {
        if lat_us.is_empty() {
            0
        } else {
            lat_us[(lat_us.len() * q / 100).min(lat_us.len() - 1)]
        }
    };
    SweepPoint {
        offered_x,
        offered_rows_per_s: submitted as f64 / wall.as_secs_f64(),
        goodput_rows_per_s: completed as f64 / wall.as_secs_f64(),
        shed_fraction: shed as f64 / (submitted.max(1)) as f64,
        completed,
        shed,
        p50_us: pct(50),
        p99_us: pct(99),
    }
}

/// The always-on section: native overload sweep + JSON artifact.
fn native_overload_sweep() {
    println!("== native overload sweep (deadline {DEADLINE:?}, 2 shards, batch 8) ==");
    let (warmup, measure) = budgets();
    let backend = native_backend();
    let pool = request_pool(TaskKind::Sst2s, 256);

    // Warm the dispatch path and page in the weights before timing.
    let _ = measure_peak(&backend, &pool, warmup);
    let peak = measure_peak(&backend, &pool, measure);
    println!("  closed-loop peak (window {WINDOW}): {peak:.1} rows/s");

    let mut sweep_json: Vec<Value> = Vec::new();
    for offered_x in OFFERED_X {
        let p = sweep_point(&backend, &pool, peak, offered_x, measure);
        println!(
            "  offered {:>4.1}x ({:>8.1} rows/s): goodput {:>8.1} rows/s, shed {:>5.1}% \
             ({} completed, {} shed), p50 {}us p99 {}us",
            p.offered_x,
            p.offered_rows_per_s,
            p.goodput_rows_per_s,
            p.shed_fraction * 100.0,
            p.completed,
            p.shed,
            p.p50_us,
            p.p99_us,
        );
        sweep_json.push(obj(vec![
            ("offered_x", p.offered_x.into()),
            ("offered_rows_per_s", p.offered_rows_per_s.into()),
            ("goodput_rows_per_s", p.goodput_rows_per_s.into()),
            ("shed_fraction", p.shed_fraction.into()),
            ("completed", (p.completed as i64).into()),
            ("shed", (p.shed as i64).into()),
            ("p50_us", (p.p50_us as i64).into()),
            ("p99_us", (p.p99_us as i64).into()),
        ]));
    }
    let shed_total = backend.shed_count() + backend.deadline_shed_count();
    println!(
        "  engine shed counters: {shed_total} total (deadline {})",
        backend.deadline_shed_count()
    );
    backend.shutdown();

    write_json(
        "serving_e2e",
        &obj(vec![
            ("bench", "serving_e2e".into()),
            ("backend", "native".into()),
            ("deadline_ms", (DEADLINE.as_millis() as i64).into()),
            ("window", (WINDOW as i64).into()),
            ("peak_rows_per_s", peak.into()),
            ("sweep", Value::Arr(sweep_json)),
        ]),
    );
}

fn main() {
    native_overload_sweep();

    let artifacts = artifacts_dir();
    let Some(spath) = summary_path(&artifacts, "bert-tiny", "sst2s") else {
        println!("\nSKIP pjrt sections: no artifacts (run `make artifacts`)");
        return;
    };
    let summary = PairSummary::load(&spath).unwrap();

    // 1. Raw PJRT execute latency, float vs HCCS variant, b1 and b8.
    println!("\n== raw model execute (PJRT, bert-tiny/sst2s) ==");
    let rt = Rc::new(Runtime::cpu().unwrap());
    let mut generator = WorkloadGen::new(TaskKind::Sst2s, 3);
    for variant in ["float", "hccs"] {
        for b in [1usize, 8] {
            let Some(mani) = summary.manifest(variant, b) else { continue };
            let runner = ModelRunner::load(rt.clone(), &artifacts, mani.clone()).unwrap();
            let _l = runner.seq_len();
            let mut ids = Vec::new();
            let mut segs = Vec::new();
            for _ in 0..b {
                let e = generator.next_example();
                ids.extend(e.ids);
                segs.extend(e.segments);
            }
            let r = bench_with(
                &format!("execute {variant} b{b}"),
                Duration::from_millis(200),
                Duration::from_millis(600),
                &mut || {
                    sink(runner.run(&ids, &segs).unwrap());
                },
            );
            println!(
                "{}  -> {:.1} examples/s",
                r.render(),
                r.per_second(b as f64)
            );
        }
    }

    // 2. Coordinator overhead: same model behind the batcher.
    println!("\n== coordinator end-to-end (batch 8, 5ms deadline) ==");
    let (coord, handle) = Coordinator::start(CoordinatorConfig {
        artifacts: artifacts.clone(),
        model: "bert-tiny".into(),
        task: "sst2s".into(),
        variant: "hccs".into(),
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
        max_in_flight: None,
        shards: 1,
    })
    .unwrap();
    let mut generator = WorkloadGen::new(TaskKind::Sst2s, 17);
    let n_req = 512usize;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_req)
        .map(|_| {
            let e = generator.next_example();
            coord.submit(e.ids, e.segments).unwrap()
        })
        .collect();
    let mut lat_us: Vec<u64> = rxs
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap().latency.as_micros() as u64)
        .collect();
    let wall = t0.elapsed();
    lat_us.sort();
    println!(
        "  {n_req} requests in {wall:?} -> {:.1} req/s; latency p50 {}us p95 {}us p99 {}us",
        n_req as f64 / wall.as_secs_f64(),
        lat_us[n_req / 2],
        lat_us[n_req * 95 / 100],
        lat_us[n_req * 99 / 100],
    );
    coord.shutdown();
    let _ = handle.join();

    // 3. Tables I/II accuracy harness timing (the "bench" of an accuracy
    // table is its regeneration cost).
    println!("\n== table regeneration ==");
    let t0 = Instant::now();
    let t1 = hccs::experiments::table1(&artifacts, 64, true).unwrap();
    println!("table1 (re-measured over 64 examples/variant): {:?}\n{t1}", t0.elapsed());
    let t0 = Instant::now();
    let t2 = hccs::experiments::table2(&artifacts).unwrap();
    println!("table2: {:?}\n{t2}", t0.elapsed());
}
