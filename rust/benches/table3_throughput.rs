//! Bench target for **Table III**: regenerates the kernel-throughput
//! table on the AIE tile model and cross-checks it against the measured
//! wall-clock throughput of the Rust reference implementation of the
//! same five-stage kernel (the shape comparison the paper makes between
//! its kernel and the BF16 reference).

use hccs::aie_sim::device::{Device, DeviceKind};
use hccs::aie_sim::kernels::KernelKind;
use hccs::aie_sim::tile::throughput_eps;
use hccs::benchkit::{bench, sink};
use hccs::experiments;
use hccs::hccs::{hccs_row_into, HccsParams, OutputPath, Reciprocal};
use hccs::rng::Xoshiro256;

/// Software emulation of the BF16 reference softmax (exp + divide) for a
/// CPU-side who-wins comparison against the integer surrogate.
fn bf16_ref_row(x: &[i8], out: &mut [f32]) {
    let m = x.iter().copied().max().unwrap() as f32;
    let mut z = 0f32;
    for (o, &xi) in out.iter_mut().zip(x) {
        let e = ((xi as f32 - m) * 0.1).exp();
        *o = e;
        z += e;
    }
    let inv = 1.0 / z;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

fn main() {
    println!("== Table III (AIE tile model) ==\n{}", experiments::table3().unwrap());

    println!("== CPU cross-check: integer HCCS vs exp-based softmax (this machine) ==");
    let mut rng = Xoshiro256::new(11);
    for n in [32usize, 64, 128] {
        let theta = HccsParams::checked((32767 / n as i32).min(300), 4, 32, n).unwrap();
        let x: Vec<i8> = (0..n).map(|_| rng.i8()).collect();
        let mut pi = vec![0i32; n];
        let mut pf = vec![0f32; n];
        let hccs = bench(&format!("rust hccs i16+div n={n}"), || {
            hccs_row_into(sink(&x), &theta, OutputPath::I16, Reciprocal::Div, &mut pi);
        });
        let bf = bench(&format!("rust exp softmax   n={n}"), || {
            bf16_ref_row(sink(&x), &mut pf);
        });
        let sp = bf.median.as_secs_f64() / hccs.median.as_secs_f64();
        println!("{}", hccs.render());
        println!("{}", bf.render());
        println!("  -> integer surrogate speedup on CPU: {sp:.2}x\n");
    }

    // Model-vs-paper drift table for EXPERIMENTS.md.
    println!("== model vs paper (elements/s) ==");
    let paper: [(DeviceKind, &[(usize, f64, f64, f64)]); 2] = [
        (
            DeviceKind::AieMl,
            &[
                (32, 0.09e9, 0.41e9, 1.36e9),
                (64, 0.16e9, 0.78e9, 2.19e9),
                (128, 0.25e9, 1.37e9, 2.18e9),
            ],
        ),
        (
            DeviceKind::AieMlV2,
            &[
                (32, 0.24e9, 0.41e9, 1.46e9),
                (64, 0.46e9, 0.78e9, 2.46e9),
                (128, 0.77e9, 1.41e9, 2.21e9),
            ],
        ),
    ];
    for (kind, rows) in paper {
        let dev = Device::new(kind);
        for &(n, p_bf, p_dv, p_cl) in rows {
            let m_bf = throughput_eps(KernelKind::Bf16Ref, &dev, n);
            let m_dv = throughput_eps(KernelKind::HccsI16Div, &dev, n);
            let m_cl = throughput_eps(KernelKind::HccsI8Clb, &dev, n);
            println!(
                "  {:<8} n={n:<4} bf16 {:.2}/{:.2}G  div {:.2}/{:.2}G  clb {:.2}/{:.2}G  (model/paper)",
                dev.short_name(),
                m_bf / 1e9,
                p_bf / 1e9,
                m_dv / 1e9,
                p_dv / 1e9,
                m_cl / 1e9,
                p_cl / 1e9
            );
        }
    }
}
