//! Bench target for the **batched HCCS engine** (`hccs_batch_into`):
//! scalar row-at-a-time vs batched tile throughput across
//! `n ∈ {16, 64, 128, 256}` and `B ∈ {1, 8, 32, 128}`, for the paper's
//! two headline modes (i16+div, i8+CLB).
//!
//! Prints one table row per (mode, n, B) with rows/s for both paths and
//! the batched/scalar speedup, then a machine-readable JSON document
//! (see EXPERIMENTS.md §batch_kernel for the schema and §Perf for how
//! these numbers are read).  When `HCCS_BENCH_JSON` is set the document
//! is also written to `BENCH_batch_kernel.json` (the CI bench
//! trajectory artifact); budgets honor `HCCS_BENCH_*_MS`.

use hccs::benchkit::{bench, sink, write_json};
use hccs::hccs::{hccs_batch_into, hccs_row_into, HccsParams, OutputPath, Reciprocal};
use hccs::json::Value;
use hccs::report::Table;
use hccs::rng::Xoshiro256;

const NS: [usize; 4] = [16, 64, 128, 256];
const BS: [usize; 4] = [1, 8, 32, 128];

fn theta(n: usize) -> HccsParams {
    // (S=1, Dmax=16) keeps the Eq. (11) band non-empty out to n=256.
    let (lo, hi) = HccsParams::feasible_b_band(1, 16, n).expect("band");
    HccsParams::checked((lo + hi) / 2, 1, 16, n).unwrap()
}

fn main() {
    let mut rng = Xoshiro256::new(23);
    let modes: [(&str, OutputPath, Reciprocal); 2] = [
        ("i16_div", OutputPath::I16, Reciprocal::Div),
        ("i8_clb", OutputPath::I8, Reciprocal::Clb),
    ];

    let mut table = Table::new(
        "batched vs scalar HCCS kernel (rows/s, this machine)",
        &["mode", "n", "B", "scalar rows/s", "batched rows/s", "speedup"],
    );
    let mut cases: Vec<Value> = Vec::new();

    for (mode, op, rc) in modes {
        for n in NS {
            let p = theta(n);
            for b in BS {
                let x: Vec<i8> = (0..b * n).map(|_| rng.i8()).collect();
                let mut out = vec![0i32; b * n];

                // Scalar path: one row-kernel call per row, exactly what
                // the pre-batching serving layers did.
                let scalar = bench(&format!("scalar {mode} n={n} B={b}"), || {
                    let x = sink(&x);
                    for r in 0..b {
                        let (lo, hi) = (r * n, (r + 1) * n);
                        hccs_row_into(&x[lo..hi], &p, op, rc, &mut out[lo..hi]);
                    }
                });
                // Batched path: the whole B x n tile in one call.
                let batched = bench(&format!("batched {mode} n={n} B={b}"), || {
                    hccs_batch_into(sink(&x), b, n, &p, op, rc, &mut out);
                });

                // Bit-exactness spot check alongside the measurement.
                let want: Vec<i32> = {
                    let mut w = vec![0i32; b * n];
                    for r in 0..b {
                        let (lo, hi) = (r * n, (r + 1) * n);
                        hccs_row_into(&x[lo..hi], &p, op, rc, &mut w[lo..hi]);
                    }
                    w
                };
                let mut got = vec![0i32; b * n];
                hccs_batch_into(&x, b, n, &p, op, rc, &mut got);
                assert_eq!(got, want, "batched output diverged at {mode} n={n} B={b}");

                let s_rps = scalar.per_second(b as f64);
                let t_rps = batched.per_second(b as f64);
                let speedup = t_rps / s_rps;
                table.row(&[
                    mode.to_string(),
                    n.to_string(),
                    b.to_string(),
                    format!("{s_rps:.3e}"),
                    format!("{t_rps:.3e}"),
                    format!("{speedup:.2}x"),
                ]);

                let mut case = std::collections::BTreeMap::new();
                case.insert("mode".to_string(), Value::from(mode));
                case.insert("n".to_string(), Value::from(n as i64));
                case.insert("batch".to_string(), Value::from(b as i64));
                case.insert("scalar_rows_per_s".to_string(), Value::from(s_rps));
                case.insert("batched_rows_per_s".to_string(), Value::from(t_rps));
                case.insert("speedup".to_string(), Value::from(speedup));
                case.insert(
                    "scalar_median_ns".to_string(),
                    Value::from(scalar.median.as_nanos() as i64),
                );
                case.insert(
                    "batched_median_ns".to_string(),
                    Value::from(batched.median.as_nanos() as i64),
                );
                cases.push(Value::Obj(case));
            }
        }
    }

    println!("{}", table.render());

    let mut doc = std::collections::BTreeMap::new();
    doc.insert("bench".to_string(), Value::from("batch_kernel"));
    doc.insert("units".to_string(), Value::from("rows_per_second"));
    doc.insert("cases".to_string(), Value::Arr(cases));
    let doc = Value::Obj(doc);
    println!("{}", doc.to_string_pretty());
    write_json("batch_kernel", &doc);
}
