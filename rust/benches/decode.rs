//! Bench target for the **autoregressive decode path**: cached-K/V
//! tokens/s of the native causal decoder (bert-tiny shapes) under every
//! softmax backend, plus the batched-session sweep and the causal
//! prefill rate.
//!
//! Four measurements feed the trajectory:
//!
//! * **batch-1 steady-state decode** — one session, one decoder step
//!   per iteration against its K/V ring; when the ring fills the cache
//!   is reset and re-prefilled inside the measured loop, so the number
//!   is the amortized tokens/s of long generations (prefill cost
//!   included at its real duty cycle).  One row per backend (f32
//!   reference + all four HCCS modes).
//! * **batched-session sweep** — `step_batch` over B independent
//!   sessions at B ∈ {1, 2, 4, 8} on the pinned i16_div mode: the
//!   projections stack across sessions into one GEMM dispatch per
//!   layer, so total tokens/s should rise with B (CI gates B=8 against
//!   the B=1 baseline).
//! * **fused-vs-unfused epilogue leg** — the 4-session `step_batch`
//!   loop with GEMM epilogue fusion forced on and off
//!   (`fused_speedup`, tracked by the trajectory).
//! * **causal prefill + end-to-end generate** — `prefill_batch` rows/s
//!   over a batch of real workload prompts, and `generate` tokens/s
//!   (prefill + greedy cached-K/V steps + stop scan) on a pinned
//!   prompt.
//!
//! Ends with a machine-readable JSON document (see EXPERIMENTS.md
//! §decode for the schema; every `*_per_s` field is tracked by
//! `tools/bench_trend.py`).  When `HCCS_BENCH_JSON` is set the
//! document is also written to `BENCH_decode.json`; budgets honor
//! `HCCS_BENCH_*_MS`.

use hccs::benchkit::{bench, sink, write_json};
use hccs::data::{TaskKind, WorkloadGen};
use hccs::json::Value;
use hccs::linalg::scoped_fused;
use hccs::model::decoder::greedy_token;
use hccs::model::{DecoderScratch, KvCache, ModelConfig, NativeDecoder, SoftmaxBackend};
use hccs::report::Table;

const PROMPTS: usize = 8;

/// Reset every session's ring, re-prefill its prompt, and leave each
/// session's next greedy token in `tokens`.
fn refill(
    dec: &NativeDecoder,
    prompts: &[Vec<i32>],
    mode: SoftmaxBackend,
    caches: &mut [KvCache],
    tokens: &mut Vec<i32>,
    s: &mut DecoderScratch,
) {
    let vocab = dec.cfg.vocab;
    tokens.clear();
    for (i, cache) in caches.iter_mut().enumerate() {
        cache.reset();
        let prompt = &prompts[i % prompts.len()];
        let rows = dec.prefill(prompt, mode, cache, s).expect("prefill");
        tokens.push(greedy_token(&rows[(prompt.len() - 1) * vocab..]));
    }
}

fn main() {
    let task = TaskKind::Sst2s;
    let cfg = ModelConfig::bert_tiny(task);
    eprintln!("calibrating native decoder bert-tiny/{}...", task.name());
    let dec = NativeDecoder::new(cfg, task, 42).expect("decoder build");

    // Prompts are the valid prefixes of real workload examples ([CLS]
    // .. [SEP]), capped so every session has at least 16 free ring
    // slots to decode into before a refill.
    let mut generator = WorkloadGen::new(task, 7);
    let prompts: Vec<Vec<i32>> = (0..PROMPTS)
        .map(|_| {
            let ex = generator.next_example();
            let n = ex.valid_len.clamp(1, cfg.seq_len - 16);
            ex.ids[..n].to_vec()
        })
        .collect();

    // ---- batch-1 steady-state decode, per backend --------------------
    let backends: Vec<SoftmaxBackend> = std::iter::once(SoftmaxBackend::F32Ref)
        .chain(SoftmaxBackend::hccs_modes())
        .collect();
    let mut table = Table::new(
        "cached-K/V decode, batch 1 (bert-tiny/sst2s, this machine)",
        &["backend", "tokens/s", "vs f32"],
    );
    let mut cases: Vec<Value> = Vec::new();
    let mut f32_tps = 0.0f64;
    for backend in backends {
        let mut scratch = DecoderScratch::default();
        let mut caches = vec![dec.new_cache()];
        let mut tokens = Vec::new();
        refill(&dec, &prompts, backend, &mut caches, &mut tokens, &mut scratch);
        let r = bench(&format!("decode b1 {}", backend.name()), || {
            if caches[0].remaining() == 0 {
                refill(&dec, &prompts, backend, &mut caches, &mut tokens, &mut scratch);
            }
            let logits = dec.step(tokens[0], backend, &mut caches[0], &mut scratch).expect("step");
            tokens[0] = greedy_token(&logits);
            sink(tokens[0]);
        });
        let tps = r.per_second(1.0);
        if backend == SoftmaxBackend::F32Ref {
            f32_tps = tps;
        }
        table.row(&[
            backend.name().to_string(),
            format!("{tps:.1}"),
            format!("{:.2}x", tps / f32_tps.max(1e-9)),
        ]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("backend".to_string(), Value::from(backend.name()));
        case.insert("tokens_per_s".to_string(), Value::from(tps));
        case.insert("median_ns".to_string(), Value::from(r.median.as_nanos() as i64));
        case.insert("speedup_vs_f32".to_string(), Value::from(tps / f32_tps.max(1e-9)));
        cases.push(Value::Obj(case));
    }
    println!("{}", table.render());

    // ---- batched-session sweep (i16_div) -----------------------------
    let mode = SoftmaxBackend::parse("i16_div").expect("known mode");
    let mut sweep_table = Table::new(
        "step_batch session sweep (i16_div)",
        &["sessions", "tokens/s", "vs b=1"],
    );
    let mut sweep: Vec<Value> = Vec::new();
    let mut b1_tps = 0.0f64;
    for &bs in &[1usize, 2, 4, 8] {
        let mut scratch = DecoderScratch::default();
        let mut caches: Vec<KvCache> = (0..bs).map(|_| dec.new_cache()).collect();
        let mut tokens = Vec::with_capacity(bs);
        refill(&dec, &prompts, mode, &mut caches, &mut tokens, &mut scratch);
        let r = bench(&format!("step_batch b={bs}"), || {
            if caches.iter().any(|c| c.remaining() == 0) {
                refill(&dec, &prompts, mode, &mut caches, &mut tokens, &mut scratch);
            }
            let out =
                dec.step_batch(&tokens, mode, &mut caches, &mut scratch).expect("step_batch");
            for (t, logits) in tokens.iter_mut().zip(&out) {
                *t = greedy_token(logits);
            }
            sink(tokens.len());
        });
        let tps = r.per_second(bs as f64);
        if bs == 1 {
            b1_tps = tps;
        }
        let speedup = tps / b1_tps.max(1e-9);
        sweep_table.row(&[bs.to_string(), format!("{tps:.1}"), format!("{speedup:.2}x")]);
        let mut case = std::collections::BTreeMap::new();
        case.insert("batch".to_string(), Value::from(bs as i64));
        case.insert("tokens_per_s".to_string(), Value::from(tps));
        case.insert("speedup_vs_b1".to_string(), Value::from(speedup));
        sweep.push(Value::Obj(case));
    }
    println!("{}", sweep_table.render());

    // ---- fused-vs-unfused epilogue dataflow (i16_div, 4 sessions) ----
    // The decode hot loop's projections run through the fused GEMM
    // epilogue by default; force it off to measure the standalone-sweep
    // dataflow it replaced (bit-exact per the proptest pins).
    const FUSED_SESSIONS: usize = 4;
    let mut fused_tps = 0.0f64;
    let mut unfused_tps = 0.0f64;
    for (label, on) in [("fused", true), ("unfused", false)] {
        let _guard = scoped_fused(on);
        let mut scratch = DecoderScratch::default();
        let mut caches: Vec<KvCache> = (0..FUSED_SESSIONS).map(|_| dec.new_cache()).collect();
        let mut tokens = Vec::with_capacity(FUSED_SESSIONS);
        refill(&dec, &prompts, mode, &mut caches, &mut tokens, &mut scratch);
        let r = bench(&format!("step_batch {label} b={FUSED_SESSIONS}"), || {
            if caches.iter().any(|c| c.remaining() == 0) {
                refill(&dec, &prompts, mode, &mut caches, &mut tokens, &mut scratch);
            }
            let out =
                dec.step_batch(&tokens, mode, &mut caches, &mut scratch).expect("step_batch");
            for (t, logits) in tokens.iter_mut().zip(&out) {
                *t = greedy_token(logits);
            }
            sink(tokens.len());
        });
        let tps = r.per_second(FUSED_SESSIONS as f64);
        if on {
            fused_tps = tps;
        } else {
            unfused_tps = tps;
        }
    }
    let fused_speedup = fused_tps / unfused_tps.max(1e-9);
    println!(
        "fused epilogues: {fused_tps:.1} vs {unfused_tps:.1} tokens/s unfused \
         ({fused_speedup:.2}x measured)"
    );

    // ---- causal prefill + end-to-end generate ------------------------
    let mut scratch = DecoderScratch::default();
    let mut ids = Vec::new();
    let mut lens = Vec::new();
    for prompt in &prompts {
        ids.extend_from_slice(prompt);
        lens.push(prompt.len());
    }
    let prefill_rows: usize = lens.iter().sum();
    let r = bench("prefill_batch", || {
        let rows = dec.prefill_batch(&ids, &lens, mode, &mut scratch).expect("prefill_batch");
        sink(rows.len());
    });
    let prefill_rows_per_s = r.per_second(prefill_rows as f64);

    // End-to-end generate on a pinned prompt: greedy decode is
    // deterministic, so the token count per call is a constant and
    // per_second stays well-defined even when a stop token ends the
    // stream before the budget.
    const GEN_BUDGET: usize = 16;
    let gen_prompt = &prompts[0];
    let warm = dec.generate(gen_prompt, GEN_BUDGET, mode, &mut scratch).expect("generate");
    let gen_tokens = warm.tokens.len().max(1);
    let r = bench("generate e2e", || {
        let g = dec.generate(gen_prompt, GEN_BUDGET, mode, &mut scratch).expect("generate");
        sink(g.tokens.len());
    });
    let generate_tokens_per_s = r.per_second(gen_tokens as f64);
    println!(
        "prefill: {prefill_rows} rows/call at {prefill_rows_per_s:.1} rows/s; \
         generate: prompt {} + {gen_tokens} tokens ({:?}) at {generate_tokens_per_s:.1} tokens/s",
        gen_prompt.len(),
        warm.stop,
    );

    let mut doc = std::collections::BTreeMap::new();
    doc.insert("bench".to_string(), Value::from("decode"));
    doc.insert("model".to_string(), Value::from("bert-tiny"));
    doc.insert("task".to_string(), Value::from(task.name()));
    doc.insert("units".to_string(), Value::from("tokens_per_second"));
    doc.insert("simd_path".to_string(), Value::from(hccs::simd::active().name()));
    doc.insert("prompt_len".to_string(), Value::from(prompts[0].len() as i64));
    doc.insert("cases".to_string(), Value::Arr(cases));
    doc.insert("batch_sweep".to_string(), Value::Arr(sweep));
    doc.insert("fused_speedup".to_string(), Value::from(fused_speedup));
    doc.insert("unfused_tokens_per_s".to_string(), Value::from(unfused_tps));
    doc.insert("prefill_rows_per_s".to_string(), Value::from(prefill_rows_per_s));
    doc.insert("generate_tokens_per_s".to_string(), Value::from(generate_tokens_per_s));
    doc.insert("generate_tokens".to_string(), Value::from(gen_tokens as i64));
    let doc = Value::Obj(doc);
    println!("{}", doc.to_string_pretty());
    write_json("decode", &doc);
}
