//! End-to-end serving driver (the E2E validation deliverable).
//!
//! Loads the QAT-retrained HCCS BERT executable through the coordinator,
//! generates a live labeled workload with the cross-language generator,
//! serves it through the dynamic batcher, and reports accuracy,
//! throughput, and latency percentiles — the serving-paper analogue of
//! "load a small real model and serve batched requests".
//!
//! Run: `make artifacts && cargo run --release --example serve_classifier -- \
//!        [--model bert-tiny] [--task sst2s] [--variant hccs] [--requests 256]`

use std::path::PathBuf;
use std::time::Instant;

use hccs::error::{anyhow, Context, Result};

use hccs::cli::Args;
use hccs::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use hccs::data::{TaskKind, WorkloadGen};

const KNOWN: &[&str] = &[
    "artifacts=", "model=", "task=", "variant=", "requests=", "batch=", "wait-ms=", "seed=",
    "shards=",
];

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), KNOWN).map_err(|e| anyhow!("{e}"))?;
    let artifacts = PathBuf::from(args.get_or("artifacts", hccs::ARTIFACTS_DIR));
    let model = args.get_or("model", "bert-tiny").to_string();
    let task_name = args.get_or("task", "sst2s").to_string();
    let variant = args.get_or("variant", "hccs").to_string();
    let requests = args.parse_num("requests", 256usize)?;
    let batch = args.parse_num("batch", 8usize)?;
    let wait_ms = args.parse_num("wait-ms", 5u64)?;
    let seed = args.parse_num("seed", 99u64)?;
    let shards = args.parse_num_at_least("shards", 1usize, 1)?;
    let task = TaskKind::parse(&task_name).context("bad --task (sst2s|mnlis)")?;

    println!(
        "== serve_classifier: {model}/{task_name}/{variant}, {requests} requests, \
         batch {batch}, {shards} shard(s)"
    );
    let (coord, handle) = Coordinator::start(CoordinatorConfig {
        artifacts,
        model,
        task: task_name.clone(),
        variant,
        policy: BatchPolicy {
            max_batch: batch,
            max_wait: std::time::Duration::from_millis(wait_ms),
        },
        max_in_flight: None,
        shards,
    })
    .context("starting coordinator — did you run `make artifacts`?")?;

    // Open-loop client: submit everything, then collect (the batcher
    // forms full batches; per-request latency includes queueing).
    let mut generator = WorkloadGen::new(task, seed);
    let mut expected = Vec::with_capacity(requests);
    let mut receivers = Vec::with_capacity(requests);
    let t0 = Instant::now();
    for _ in 0..requests {
        let ex = generator.next_example();
        expected.push(ex.label);
        receivers.push(coord.submit(ex.ids, ex.segments)?);
    }
    let mut correct = 0usize;
    let mut latencies_us: Vec<u64> = Vec::with_capacity(requests);
    for (rx, want) in receivers.into_iter().zip(&expected) {
        let reply = rx
            .recv()
            .context("engine dropped request")?
            .map_err(|e| anyhow!("{e}"))?;
        correct += (reply.predicted as i32 == *want) as usize;
        latencies_us.push(reply.latency.as_micros() as u64);
    }
    let wall = t0.elapsed();
    coord.shutdown();
    let _ = handle.join();

    latencies_us.sort();
    let pct = |p: f64| latencies_us[((latencies_us.len() - 1) as f64 * p) as usize];
    println!("\nresults:");
    println!("  accuracy    : {:.4} ({correct}/{requests})", correct as f64 / requests as f64);
    println!("  wall time   : {wall:?}");
    println!("  throughput  : {:.1} req/s", requests as f64 / wall.as_secs_f64());
    println!(
        "  latency     : p50 {}us  p95 {}us  p99 {}us  max {}us",
        pct(0.50), pct(0.95), pct(0.99), latencies_us.last().unwrap()
    );
    println!("\ncoordinator metrics:\n{}", coord.metrics.render());
    Ok(())
}
