//! End-to-end serving driver (the E2E validation deliverable).
//!
//! Generates a live labeled workload with the cross-language generator,
//! serves it, and reports accuracy, throughput, and latency
//! percentiles.  Two backends behind the same [`InferBackend`] trait:
//!
//! * `--backend native` (default) — the pure-Rust integer encoder
//!   (`rust/src/model/`), seeded + calibrated at startup: runs on a
//!   fresh clone with **zero artifacts**.  `--mode` picks the softmax
//!   backend (i16_div | i16_clb | i8_div | i8_clb | f32); `--shards`,
//!   `--max-batch`, `--wait-ms`, and `--length-bands` configure the
//!   sharded executor pool batching requests into `forward_batch`
//!   tiles (length bands keep short-traffic tiles narrow).
//! * `--backend pjrt` — the QAT-retrained HCCS BERT executable through
//!   the sharded coordinator (requires `make artifacts`).
//!
//! Run: `cargo run --release --example serve_classifier -- \
//!        [--backend native|pjrt] [--model bert-tiny] [--task sst2s] [--requests 256]`

use std::path::PathBuf;
use std::time::{Duration, Instant};

use hccs::error::{anyhow, Context, Result};

use hccs::cli::Args;
use hccs::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use hccs::data::{TaskKind, WorkloadGen};
use hccs::model::{ModelConfig, NativeBackend, NativeModel, NativeServeConfig, SoftmaxBackend};
use hccs::server::InferBackend;

const KNOWN: &[&str] = &[
    "artifacts=", "model=", "task=", "variant=", "requests=", "batch=", "max-batch=",
    "wait-ms=", "seed=", "shards=", "length-bands=", "backend=", "mode=", "model-seed=",
];

/// Open-loop client over any inference backend: submit everything,
/// then collect (per-request latency includes queueing where the
/// backend batches).
fn run_workload<B: InferBackend>(
    backend: &B,
    task: TaskKind,
    requests: usize,
    seed: u64,
) -> Result<(usize, Vec<u64>, Duration)> {
    let mut generator = WorkloadGen::new(task, seed);
    let mut expected = Vec::with_capacity(requests);
    let mut receivers = Vec::with_capacity(requests);
    let t0 = Instant::now();
    for _ in 0..requests {
        let ex = generator.next_example();
        expected.push(ex.label);
        receivers.push(backend.submit_request(ex.ids, ex.segments)?);
    }
    let mut correct = 0usize;
    let mut latencies_us: Vec<u64> = Vec::with_capacity(requests);
    for (rx, want) in receivers.into_iter().zip(&expected) {
        let reply = rx
            .recv()
            .context("engine dropped request")?
            .map_err(|e| anyhow!("{e}"))?;
        correct += usize::from(reply.predicted as i32 == *want);
        latencies_us.push(reply.latency.as_micros() as u64);
    }
    Ok((correct, latencies_us, t0.elapsed()))
}

fn report(requests: usize, correct: usize, mut latencies_us: Vec<u64>, wall: Duration) {
    latencies_us.sort();
    let pct = |p: f64| latencies_us[((latencies_us.len() - 1) as f64 * p) as usize];
    println!("\nresults:");
    println!("  accuracy    : {:.4} ({correct}/{requests})", correct as f64 / requests as f64);
    println!("  wall time   : {wall:?}");
    println!("  throughput  : {:.1} req/s", requests as f64 / wall.as_secs_f64());
    println!(
        "  latency     : p50 {}us  p95 {}us  p99 {}us  max {}us",
        pct(0.50),
        pct(0.95),
        pct(0.99),
        latencies_us.last().unwrap()
    );
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), KNOWN).map_err(|e| anyhow!("{e}"))?;
    let artifacts = PathBuf::from(args.get_or("artifacts", hccs::ARTIFACTS_DIR));
    let model = args.get_or("model", "bert-tiny").to_string();
    let task_name = args.get_or("task", "sst2s").to_string();
    let variant = args.get_or("variant", "hccs").to_string();
    let requests = args.parse_num_at_least("requests", 256usize, 1)?;
    let batch = args.parse_num("batch", 8usize)?;
    let wait_ms = args.parse_num("wait-ms", 5u64)?;
    let seed = args.parse_num("seed", 99u64)?;
    let shards = args.parse_num_at_least("shards", 1usize, 1)?;
    let task = TaskKind::parse(&task_name).context("bad --task (sst2s|mnlis)")?;

    match args.get_or("backend", "native") {
        "native" => {
            // Same misconfiguration guard as `hccs serve`: don't let
            // pjrt-only flags be dropped silently.  (--shards,
            // --max-batch, and --wait-ms apply to the native backend.)
            for flag in ["variant", "batch", "artifacts"] {
                if args.get(flag).is_some() {
                    eprintln!(
                        "warning: --{flag} only applies to --backend pjrt; \
                         ignored by the native backend"
                    );
                }
            }
            let mode = SoftmaxBackend::parse(args.get_or("mode", "i16_div"))
                .context("bad --mode (i16_div|i16_clb|i8_div|i8_clb|f32)")?;
            let model_seed = args.parse_num("model-seed", 42u64)?;
            let max_batch = args.parse_num_at_least("max-batch", 8usize, 1)?;
            let length_bands = args.parse_num_at_least("length-bands", 1usize, 1)?;
            let cfg = ModelConfig::parse(&model, task)
                .with_context(|| format!("unknown --model {model:?} (bert-tiny|bert-small)"))?;
            println!(
                "== serve_classifier: native {model}/{task_name} softmax={}, \
                 {requests} requests, max batch {max_batch}, {shards} shard(s), \
                 {length_bands} length band(s) (zero artifacts)",
                mode.name()
            );
            let native = NativeModel::new(cfg, task, model_seed)?;
            let front = NativeBackend::with_config(
                std::sync::Arc::new(native),
                mode,
                NativeServeConfig {
                    policy: BatchPolicy {
                        max_batch,
                        max_wait: Duration::from_millis(wait_ms),
                    },
                    shards,
                    length_bands,
                    max_in_flight: None,
                },
            )?;
            let (correct, latencies, wall) = run_workload(&front, task, requests, seed)?;
            front.shutdown();
            report(requests, correct, latencies, wall);
            println!("\nnative backend metrics:\n{}", front.metrics.render());
        }
        "pjrt" => {
            println!(
                "== serve_classifier: pjrt {model}/{task_name}/{variant}, {requests} requests, \
                 batch {batch}, {shards} shard(s)"
            );
            let (coord, handle) = Coordinator::start(CoordinatorConfig {
                artifacts,
                model,
                task: task_name.clone(),
                variant,
                policy: BatchPolicy {
                    max_batch: batch,
                    max_wait: Duration::from_millis(wait_ms),
                },
                max_in_flight: None,
                shards,
            })
            .context("starting coordinator — did you run `make artifacts`?")?;
            let (correct, latencies, wall) = run_workload(&coord, task, requests, seed)?;
            coord.shutdown();
            let _ = handle.join();
            report(requests, correct, latencies, wall);
            println!("\ncoordinator metrics:\n{}", coord.metrics.render());
        }
        other => return Err(anyhow!("unknown --backend {other:?} (native|pjrt)")),
    }
    Ok(())
}
