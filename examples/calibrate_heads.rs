//! Per-head calibration walkthrough (paper §III-C + Table II intuition).
//!
//! Synthesizes three attention heads with very different statistics — a
//! broad head, a focused head, and a heavy-tailed head — then calibrates
//! θ_h per-head and globally, showing (i) the grid search adapts slope
//! and clamp to each head, and (ii) per-head calibration dominates the
//! shared/global parameterization in KL, which is exactly the Table II
//! mechanism.  Also loads real artifact calibrations when present.
//!
//! Run: `cargo run --release --example calibrate_heads`

use std::path::PathBuf;

use hccs::error::Result;

use hccs::coordinator::HeadParamStore;
use hccs::hccs::calibrate::{calibrate_rows, calibrate_scale, quantize_i8};
use hccs::hccs::kernel::{hccs_rows, OutputPath, Reciprocal};
use hccs::hccs::stats::{kl, mean, normalize_phat, softmax};
use hccs::report::Table;
use hccs::rng::Xoshiro256;

fn synth_head(rng: &mut Xoshiro256, n: usize, rows: usize, kind: &str) -> Vec<Vec<f64>> {
    (0..rows)
        .map(|_| {
            (0..n)
                .map(|i| match kind {
                    // Broad: small logit spread, mass over many keys.
                    "broad" => (rng.f64() + rng.f64() - 1.0) * 1.5,
                    // Focused: one dominant key per row.
                    "focused" => {
                        if i == (rng.next_u64() % 4) as usize {
                            6.0 + rng.f64() * 4.0
                        } else {
                            (rng.f64() - 0.5) * 2.0
                        }
                    }
                    // Heavy-tailed: occasional large outliers.
                    _ => {
                        let v = (rng.f64() + rng.f64() - 1.0) * 2.0;
                        if rng.chance(1, 16) { v * 6.0 } else { v }
                    }
                })
                .collect()
        })
        .collect()
}

fn main() -> Result<()> {
    let n = 64usize;
    let mut rng = Xoshiro256::new(7);
    let heads: Vec<(&str, Vec<Vec<f64>>)> = vec![
        ("broad", synth_head(&mut rng, n, 192, "broad")),
        ("focused", synth_head(&mut rng, n, 192, "focused")),
        ("heavy-tail", synth_head(&mut rng, n, 192, "tail")),
    ];

    // Per-head calibration.
    let mut t = Table::new(
        "Per-head calibration (synthetic heads, n=64)",
        &["head", "B", "S", "Dmax", "gamma", "KL per-head", "KL global"],
    );
    let pooled: Vec<Vec<f64>> = heads.iter().flat_map(|(_, r)| r.clone()).collect();
    let g_pool = calibrate_scale(&pooled.iter().flatten().cloned().collect::<Vec<_>>(), 99.9);
    let global = calibrate_rows(&pooled, n, g_pool);

    for (name, rows) in &heads {
        let flat: Vec<f64> = rows.iter().flatten().cloned().collect();
        let gamma = calibrate_scale(&flat, 99.9);
        let cal = calibrate_rows(rows, n, gamma);
        // Evaluate the *global* θ on this head's rows for the ablation gap.
        let xq: Vec<i8> = rows.iter().flat_map(|r| quantize_i8(r, global.gamma)).collect();
        let thetas = vec![global.params; rows.len()];
        let phat = hccs_rows(&xq, n, &thetas, OutputPath::I16, Reciprocal::Div);
        let kl_global = mean(
            &rows
                .iter()
                .enumerate()
                .map(|(r, row)| kl(&softmax(row), &normalize_phat(&phat[r * n..(r + 1) * n])))
                .collect::<Vec<_>>(),
        );
        t.row(&[
            name.to_string(),
            cal.params.b.to_string(),
            cal.params.s.to_string(),
            cal.params.dmax.to_string(),
            format!("{:.4}", cal.gamma),
            format!("{:.4}", cal.kl),
            format!("{kl_global:.4}"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "global θ = (B={}, S={}, Dmax={}) — note the per-head column never loses,\n\
         and heterogeneous heads (focused vs broad) gain the most: the Table II effect.\n",
        global.params.b, global.params.s, global.params.dmax
    );

    // Real artifacts, if built.
    let artifacts = PathBuf::from(hccs::ARTIFACTS_DIR);
    for (model, task, n) in [("bert-tiny", "sst2s", 64usize), ("bert-small", "mnlis", 128)] {
        for suffix in ["", "_fast"] {
            let p = artifacts.join(format!("calib_{model}_{task}{suffix}.json"));
            if p.exists() {
                let store = HeadParamStore::load(&p, n)?;
                println!(
                    "artifact calibration {model}/{task}: {} layers x {} heads, \
                     mean per-head KL {:.3}, global KL {:.3}",
                    store.per_head.layers,
                    store.per_head.heads,
                    mean(&store.per_head.kl),
                    mean(&store.global.kl),
                );
                break;
            }
        }
    }
    Ok(())
}
