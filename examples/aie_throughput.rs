//! Regenerate the paper's hardware evaluation on the AIE tile model:
//! Table III (kernel throughput + speedups, both device generations),
//! the CLB reciprocal ablation (§III-B-c), and Fig. 3 (multi-tile
//! scaling), with per-stage cycle attribution.
//!
//! Run: `cargo run --release --example aie_throughput`

use hccs::error::Result;

use hccs::aie_sim::device::{Device, DeviceKind};
use hccs::aie_sim::kernels::KernelKind;
use hccs::aie_sim::tile::TileSim;
use hccs::experiments;

fn main() -> Result<()> {
    println!("{}", experiments::table3()?);
    println!("{}", experiments::clb_ablation());
    println!("{}", experiments::fig3()?);

    // Capacity planning: array share the softmax stage needs for real
    // encoder workloads (the paper's "a full DNN workload will not
    // typically allocate such a large portion of the array" remark).
    println!("softmax tile allocation for encoder inference traces (AIE-MLv2):");
    let dev = Device::new(DeviceKind::AieMlV2);
    for kernel in [KernelKind::Bf16Ref, KernelKind::HccsI8Clb] {
        println!("  {}:", kernel.name());
        for (name, rate, alloc) in hccs::aie_sim::trace::share_table(&dev, kernel) {
            println!(
                "    {name:<18} @ {rate:>7.0}/s -> {:>3} tiles ({:>5.1}% of array), \
                 occ {:>4.0}%, softmax latency {:.1}us",
                alloc.tiles,
                alloc.array_share * 100.0,
                alloc.occupancy * 100.0,
                alloc.latency_s * 1e6
            );
        }
    }
    println!();

    // MAC-utilization view (the §Perf "roofline" for the integer path).
    println!("int8 MAC utilization (HCCS kernels, n=128):");
    for kind in [DeviceKind::AieMl, DeviceKind::AieMlV2] {
        let dev = Device::new(kind);
        for k in [KernelKind::HccsI16Div, KernelKind::HccsI8Clb] {
            let sim = TileSim::new(dev, k);
            println!(
                "  {:<10} {:<14} {:.1}% of {} MACs/cycle peak",
                dev.short_name(),
                k.name(),
                sim.mac_utilization(128) * 100.0,
                dev.peak_int8_macs
            );
        }
    }
    Ok(())
}
