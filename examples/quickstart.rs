//! Quickstart: the HCCS surrogate end to end in five minutes.
//!
//! 1. run the *Rust* integer kernel on a batch of synthetic int8 logits;
//! 2. load the *Pallas-kernel HLO artifact* and run the same batch
//!    through PJRT, asserting bit-exact agreement;
//! 3. compare both against exact float softmax (KL divergence) to show
//!    the surrogate tracks the real distribution.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::path::PathBuf;
use std::rc::Rc;

use hccs::error::{Context, Result};

use hccs::hccs::stats::{kl, normalize_phat, softmax};
use hccs::hccs::{hccs_row, HccsParams, OutputPath, Reciprocal};
use hccs::rng::Xoshiro256;
use hccs::runtime::{KernelRunner, Runtime};

fn main() -> Result<()> {
    let artifacts = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| hccs::ARTIFACTS_DIR.to_string()),
    );
    let (rows, n) = (8usize, 64usize);

    // Synthetic attention logits: a few sharp rows, a few broad ones.
    let mut rng = Xoshiro256::new(1);
    let mut x_f64 = vec![vec![0f64; n]; rows];
    for (r, row) in x_f64.iter_mut().enumerate() {
        let spread = if r % 2 == 0 { 2.0 } else { 8.0 };
        for v in row.iter_mut() {
            *v = (rng.f64() + rng.f64() - 1.0) * spread;
        }
    }

    // Quantize with a simple symmetric scale and pick a feasible θ.
    let gamma = 8.0 / 127.0;
    let x_i8: Vec<i8> = x_f64
        .iter()
        .flatten()
        .map(|&v| (v / gamma).round().clamp(-128.0, 127.0) as i8)
        .collect();
    let theta = HccsParams::checked(300, 4, 64, n).context("infeasible θ")?;
    println!("θ = (B={}, S={}, Dmax={}),  n={n},  feasible ✓", theta.b, theta.s, theta.dmax);

    // 1. Rust integer kernel.
    println!("\n-- Rust HCCS core (i16+div) --");
    let mut rust_out = Vec::new();
    for r in 0..rows {
        let phat = hccs_row(&x_i8[r * n..(r + 1) * n], &theta, OutputPath::I16, Reciprocal::Div);
        let p_ref = softmax(&x_f64[r]);
        let d = kl(&p_ref, &normalize_phat(&phat));
        let sum: i32 = phat.iter().sum();
        println!("  row {r}: Σp̂ = {sum:>5}, KL(softmax ‖ HCCS) = {d:.4} nats");
        rust_out.extend(phat);
    }

    // 2. The AOT Pallas kernel through PJRT (if artifacts are built).
    let hlo = artifacts.join("hccs_softmax_i16_div_n64.hlo.txt");
    if hlo.exists() {
        println!("\n-- Pallas kernel artifact via PJRT ({}) --", hlo.display());
        let rt = Rc::new(Runtime::cpu()?);
        println!("  platform: {}", rt.platform());
        let runner = KernelRunner::load(rt, &hlo, rows, n)?;
        let b = vec![theta.b; rows];
        let s = vec![theta.s; rows];
        let d = vec![theta.dmax; rows];
        let xla_out = runner.run(&x_i8, &b, &s, &d)?;
        assert_eq!(xla_out, rust_out, "PJRT kernel and Rust core disagree!");
        println!("  bit-exact with the Rust core across {rows}x{n} ✓");
    } else {
        println!("\n(skipping PJRT round-trip: run `make artifacts` to build {})", hlo.display());
    }

    // 3. CLB variant: same ordering, ≤2x overshoot, no divide.
    println!("\n-- CLB reciprocal variant (i8+CLB) --");
    let phat_div = hccs_row(&x_i8[..n], &theta, OutputPath::I8, Reciprocal::Div);
    let phat_clb = hccs_row(&x_i8[..n], &theta, OutputPath::I8, Reciprocal::Clb);
    println!("  Σp̂ div = {}, Σp̂ clb = {} (CLB overestimates ≤2x, order preserved)",
        phat_div.iter().sum::<i32>(), phat_clb.iter().sum::<i32>());
    let rank = |p: &[i32]| {
        let mut idx: Vec<usize> = (0..p.len()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(p[i]));
        idx
    };
    assert_eq!(rank(&phat_div)[..5], rank(&phat_clb)[..5], "top-5 rank changed");
    println!("  top-5 attention ranks identical ✓");
    println!("\nquickstart OK");
    Ok(())
}
